//! The network fabric: latency + per-node capacity + FIFO link contention.
//!
//! The paper's headline time-to-accuracy numbers come from simulating
//! *heterogeneous* networks: pairwise WAN latency **and per-node network
//! capacity** from realistic traces (§4.2). This module composes the
//! [`LatencyMatrix`], the [`TrafficLedger`], and per-node uplink/downlink
//! capacities into one [`NetworkFabric`] every protocol session charges its
//! transfers against.
//!
//! Transfers are scheduled through FIFO per-link queues: a node's concurrent
//! sends serialize on its uplink (and a node's concurrent receives on its
//! downlink) instead of each being charged the full capacity independently.
//! This is what makes thin/slow nodes actually inflate round duration — an
//! aggregator pushing `s` models back-to-back pays `s` transfer times on its
//! uplink, exactly as a real socket would.
//!
//! Capacity model per transfer of `B` bytes from `i` to `j` (pipelined
//! store-and-forward: each link is occupied at its own rate, the slower
//! side is the bottleneck, a symmetric-capacity pair charges the transfer
//! once):
//!
//! ```text
//! up_tx      = 8·B / up(i)                      (sender-uplink occupancy)
//! down_tx    = 8·B / down(j)                    (receiver-downlink occupancy)
//! up_start   = max(now, up_free(i))             (FIFO on i's uplink)
//! up_end     = up_start + up_tx;  up_free(i) = up_end
//! down_start = max(up_start + latency(i,j), down_free(j))
//! down_end   = down_start + down_tx;  down_free(j) = down_end
//! deliver    = max(down_end, up_end + latency(i,j))
//! ```
//!
//! Each link queue advances only by its *own* occupancy (`down_free` by
//! `down_end`, not by `deliver`), so a slow sender's upload delays its own
//! delivery but never head-of-line-blocks the receiver's other, faster
//! incoming transfers.
//!
//! Successive occupancy windows on one link never overlap (see
//! `prop_invariants.rs`), and an unlimited-capacity endpoint (the FedAvg
//! server override) contributes zero occupancy on its own side while the
//! finite peer still pays.

use super::latency::LatencyMatrix;
use super::loss::{LossLayer, LossModel};
use super::message::MsgKind;
use super::traffic::TrafficLedger;
use crate::sim::{SimRng, SimTime};
use crate::NodeId;

/// Cap a single transfer's link occupancy (guards degenerate configs, same
/// bound the pre-fabric sessions used).
const MAX_TRANSFER_SECS: f64 = 3600.0;

/// One capacity tier of a trace-style bandwidth distribution.
#[derive(Debug, Clone)]
pub struct BandwidthClass {
    /// Relative weight of this tier (need not sum to 1).
    pub weight: f64,
    pub up_bps: f64,
    pub down_bps: f64,
}

/// How per-node uplink/downlink capacities are assigned.
///
/// Replaces the old global scalar `bandwidth_bps`: capacities are per node,
/// possibly asymmetric, and sampled deterministically from the session seed.
#[derive(Debug, Clone)]
pub enum BandwidthConfig {
    /// Every node gets the same symmetric capacity (the pre-fabric
    /// behaviour, minus the contention model).
    Uniform { bps: f64 },
    /// Symmetric capacities sampled lognormally around `median_bps`
    /// (factor clamped to [0.1, 10] like the compute-speed model).
    LogNormal { median_bps: f64, sigma: f64 },
    /// Weighted capacity tiers — the shape of FCC/speedtest-style traces
    /// (e.g. fiber / cable / DSL / mobile).
    Classes(Vec<BandwidthClass>),
    /// Explicit per-node capacities (trace playback). Nodes beyond the
    /// vectors reuse the last entry.
    PerNode { up_bps: Vec<f64>, down_bps: Vec<f64> },
}

impl BandwidthConfig {
    pub fn uniform_mbps(mbps: f64) -> BandwidthConfig {
        BandwidthConfig::Uniform { bps: mbps * 1e6 }
    }

    /// Capacity of node `idx` under this config, drawing from `rng` where
    /// the config is stochastic. Callers must invoke this once per node in
    /// index order for reproducibility. The third element is the chosen
    /// class-tier index (0 for non-`Classes` configs) — the `classes` loss
    /// model keys its per-tier drop rates off it.
    fn sample_one(&self, idx: usize, rng: &mut SimRng) -> (f64, f64, u32) {
        match self {
            BandwidthConfig::Uniform { bps } => (*bps, *bps, 0),
            BandwidthConfig::LogNormal { median_bps, sigma } => {
                let f = (sigma * rng.next_gaussian()).exp().clamp(0.1, 10.0);
                let bps = median_bps * f;
                (bps, bps, 0)
            }
            BandwidthConfig::Classes(classes) => {
                assert!(!classes.is_empty(), "empty bandwidth class list");
                // A NaN/∞/non-positive weight would silently skew the
                // cumulative walk toward the last class; fail loudly
                // instead (config-file paths validate earlier with a
                // recoverable error, this guards programmatic use).
                let mut total = 0.0;
                for c in classes {
                    assert!(
                        c.weight.is_finite() && c.weight > 0.0,
                        "bandwidth class weight must be a finite positive number, got {}",
                        c.weight
                    );
                    total += c.weight;
                }
                let mut pick = rng.next_f64() * total;
                for (i, c) in classes.iter().enumerate() {
                    pick -= c.weight;
                    if pick <= 0.0 {
                        return (c.up_bps, c.down_bps, i as u32);
                    }
                }
                let last = classes.last().unwrap();
                (last.up_bps, last.down_bps, classes.len() as u32 - 1)
            }
            BandwidthConfig::PerNode { up_bps, down_bps } => {
                assert!(
                    !up_bps.is_empty() && !down_bps.is_empty(),
                    "empty per-node bandwidth vectors"
                );
                let up = *up_bps.get(idx).unwrap_or(up_bps.last().unwrap());
                let down = *down_bps.get(idx).unwrap_or(down_bps.last().unwrap());
                (up, down, 0)
            }
        }
    }
}

/// The scheduling outcome of one transfer: when each link was occupied and
/// when the receiver got the last byte.
#[derive(Debug, Clone, Copy)]
pub struct TransferPlan {
    pub up_start: SimTime,
    pub up_end: SimTime,
    pub down_start: SimTime,
    pub down_end: SimTime,
    pub delivered: SimTime,
}

/// Latency matrix + per-node capacities + FIFO link queues + traffic ledger.
pub struct NetworkFabric {
    latency: LatencyMatrix,
    ledger: TrafficLedger,
    cfg: BandwidthConfig,
    up_bps: Vec<f64>,
    down_bps: Vec<f64>,
    /// Bandwidth-class tier each node sampled (0 outside `Classes`).
    tier: Vec<u32>,
    up_free: Vec<SimTime>,
    down_free: Vec<SimTime>,
    /// Bytes charged against link capacity (invariant: equals ledger total).
    charged: u64,
    /// RNG stream for capacities of nodes joining after construction.
    growth_rng: SimRng,
    /// Fault injection; [`LossLayer::disabled`] unless the scenario
    /// configures `network.loss`.
    loss: LossLayer,
}

impl NetworkFabric {
    /// Assign capacities to `nodes` nodes from `bw`, deterministically from
    /// `rng` (fork a labelled stream from the session seed).
    pub fn new(
        latency: LatencyMatrix,
        bw: &BandwidthConfig,
        nodes: usize,
        rng: &mut SimRng,
    ) -> NetworkFabric {
        let growth_rng = rng.fork("fabric-growth");
        let mut up_bps = Vec::with_capacity(nodes);
        let mut down_bps = Vec::with_capacity(nodes);
        let mut tier = Vec::with_capacity(nodes);
        for i in 0..nodes {
            let (u, d, t) = bw.sample_one(i, rng);
            up_bps.push(u);
            down_bps.push(d);
            tier.push(t);
        }
        NetworkFabric {
            latency,
            ledger: TrafficLedger::new(nodes),
            cfg: bw.clone(),
            up_bps,
            down_bps,
            tier,
            up_free: vec![SimTime::ZERO; nodes],
            down_free: vec![SimTime::ZERO; nodes],
            charged: 0,
            growth_rng,
            loss: LossLayer::disabled(),
        }
    }

    /// Install a fault-injection model with its dedicated RNG stream (the
    /// scenario layer forks `"loss"` off the run seed). Absent this call
    /// the fabric delivers exactly once, bit-identical to pre-loss builds.
    pub fn set_loss(&mut self, model: LossModel, rng: SimRng) {
        self.loss = LossLayer::new(model, rng);
    }

    /// Whether fault injection is active (drives whether protocols arm
    /// their reliability layer).
    pub fn has_loss(&self) -> bool {
        self.loss.enabled()
    }

    /// The bandwidth-class tier `node` sampled (0 outside `Classes`).
    pub fn tier(&self, node: NodeId) -> u32 {
        self.tier[node as usize]
    }

    /// Uniform-capacity convenience constructor (tests, benches).
    pub fn uniform(latency: LatencyMatrix, bps: f64, nodes: usize) -> NetworkFabric {
        let mut rng = SimRng::new(0);
        NetworkFabric::new(latency, &BandwidthConfig::Uniform { bps }, nodes, &mut rng)
    }

    pub fn nodes(&self) -> usize {
        self.up_bps.len()
    }

    pub fn up_bps(&self, node: NodeId) -> f64 {
        self.up_bps[node as usize]
    }

    pub fn down_bps(&self, node: NodeId) -> f64 {
        self.down_bps[node as usize]
    }

    /// Per-node capacity override: unlimited up/down. This is how the
    /// FedAvg emulation grants its server "unlimited bandwidth capacity"
    /// (paper §4.3) — an override, not a protocol special case.
    pub fn set_unlimited(&mut self, node: NodeId) {
        self.ensure_nodes(node as usize + 1);
        self.up_bps[node as usize] = f64::INFINITY;
        self.down_bps[node as usize] = f64::INFINITY;
    }

    /// Grow capacity tables (and the ledger) when churn introduces nodes
    /// beyond the initial population. Steady-state cost is one comparison.
    pub fn ensure_nodes(&mut self, nodes: usize) {
        if nodes <= self.up_bps.len() {
            return;
        }
        while self.up_bps.len() < nodes {
            let idx = self.up_bps.len();
            let (u, d, t) = self.cfg.sample_one(idx, &mut self.growth_rng);
            self.up_bps.push(u);
            self.down_bps.push(d);
            self.tier.push(t);
            self.up_free.push(SimTime::ZERO);
            self.down_free.push(SimTime::ZERO);
        }
        self.ledger.ensure_nodes(nodes);
    }

    pub fn latency(&self) -> &LatencyMatrix {
        &self.latency
    }

    pub fn one_way(&self, a: NodeId, b: NodeId) -> SimTime {
        self.latency.one_way(a, b)
    }

    /// Minimum pairwise one-way latency of the quantized matrix — exported
    /// as the conservative lookahead of the sharded scheduler
    /// ([`crate::sim::parallel`]). Zero means the session has a
    /// zero-latency link and no conservative window exists.
    pub fn min_one_way(&self) -> SimTime {
        self.latency.min_one_way()
    }

    pub fn ledger(&self) -> &TrafficLedger {
        &self.ledger
    }

    pub fn ledger_mut(&mut self) -> &mut TrafficLedger {
        &mut self.ledger
    }

    pub fn into_ledger(self) -> TrafficLedger {
        self.ledger
    }

    /// Total bytes scheduled through link capacity so far.
    pub fn charged_bytes(&self) -> u64 {
        self.charged
    }

    fn tx_time(bytes: u64, bps: f64) -> SimTime {
        if !bps.is_finite() {
            return SimTime::ZERO; // unlimited capacity: zero occupancy
        }
        if bps <= 0.0 {
            // A dead link in a trace is the slowest node, not a teleporter.
            return SimTime::from_secs_f64(MAX_TRANSFER_SECS);
        }
        SimTime::from_secs_f64(((bytes as f64 * 8.0) / bps).min(MAX_TRANSFER_SECS))
    }

    /// Serialize the fabric's *dynamic* state: per-node capacities (they
    /// can include growth-sampled and `set_unlimited`-overridden entries,
    /// so the spec alone cannot reproduce them), the FIFO link clocks, the
    /// charged-bytes counter, the growth RNG stream, and the ledger. The
    /// latency matrix and bandwidth config are static — rebuilt from the
    /// scenario spec on restore.
    pub fn write_into(&self, w: &mut crate::sim::SnapshotWriter) {
        w.write_usize(self.up_bps.len());
        for i in 0..self.up_bps.len() {
            w.write_f64(self.up_bps[i]);
            w.write_f64(self.down_bps[i]);
            w.write_u32(self.tier[i]);
            w.write_time(self.up_free[i]);
            w.write_time(self.down_free[i]);
        }
        w.write_u64(self.charged);
        w.write_rng(&self.growth_rng);
        self.ledger.write_into(w);
        self.loss.write_into(w);
    }

    /// Overwrite the dynamic state of a freshly spec-built fabric with a
    /// snapshot's. The latency matrix and bandwidth config of `self` are
    /// kept (they are derived from the same spec embedded in the snapshot).
    pub fn restore_from(&mut self, r: &mut crate::sim::SnapshotReader) -> anyhow::Result<()> {
        let n = r.read_usize()?;
        self.up_bps.clear();
        self.down_bps.clear();
        self.tier.clear();
        self.up_free.clear();
        self.down_free.clear();
        for _ in 0..n {
            self.up_bps.push(r.read_f64()?);
            self.down_bps.push(r.read_f64()?);
            self.tier.push(r.read_u32()?);
            self.up_free.push(r.read_time()?);
            self.down_free.push(r.read_time()?);
        }
        self.charged = r.read_u64()?;
        self.growth_rng = r.read_rng()?;
        self.ledger = TrafficLedger::read_from(r)?;
        self.loss.restore_from(r)?;
        Ok(())
    }

    /// Schedule `bytes` from `from` to `to` starting no earlier than `now`,
    /// advancing both FIFO link queues. An unlimited-capacity side (the
    /// FedAvg server override) has zero occupancy: it neither waits on nor
    /// advances its queue, so its transfers overlap freely. Pure capacity
    /// accounting — the ledger is only touched by
    /// [`NetworkFabric::transfer`].
    pub fn plan(&mut self, now: SimTime, from: NodeId, to: NodeId, bytes: u64) -> TransferPlan {
        self.ensure_nodes(from.max(to) as usize + 1);
        let (f, t) = (from as usize, to as usize);
        let up_limited = self.up_bps[f].is_finite();
        let down_limited = self.down_bps[t].is_finite();
        let up_tx = Self::tx_time(bytes, self.up_bps[f]);
        let down_tx = Self::tx_time(bytes, self.down_bps[t]);
        let up_start = if up_limited { now.max(self.up_free[f]) } else { now };
        let up_end = up_start + up_tx;
        if up_limited {
            self.up_free[f] = up_end;
        }
        let lat = self.latency.one_way(from, to);
        let arrival = up_start + lat;
        let down_start = if down_limited { arrival.max(self.down_free[t]) } else { arrival };
        let down_end = down_start + down_tx;
        let delivered = down_end.max(up_end + lat);
        if down_limited {
            // Advance the downlink only by its own occupancy: a slow
            // sender's upload must not head-of-line-block other receives.
            self.down_free[t] = down_end;
        }
        self.charged += bytes;
        TransferPlan { up_start, up_end, down_start, down_end, delivered }
    }

    /// Account `parts` in the ledger and schedule the transfer; returns the
    /// absolute virtual time of delivery. Loss-exempt: tests, benches, and
    /// invariant props that reason about exactly-once delivery use this
    /// directly; session traffic goes through [`NetworkFabric::try_transfer`].
    pub fn transfer(
        &mut self,
        now: SimTime,
        from: NodeId,
        to: NodeId,
        parts: &[(MsgKind, u64)],
    ) -> SimTime {
        let bytes: u64 = parts.iter().map(|(_, b)| b).sum();
        // plan() grows fabric + ledger tables; record_parts then only pays
        // a cheap length check.
        let plan = self.plan(now, from, to, bytes);
        self.ledger.record_parts(from, to, parts);
        plan.delivered
    }

    /// Occupy the sender's uplink for a transfer that is lost in flight:
    /// the bytes left the sender (wire cost, FIFO occupancy, charge) but
    /// never reach `to`'s downlink.
    fn plan_dropped(&mut self, now: SimTime, from: NodeId, bytes: u64) {
        let f = from as usize;
        if self.up_bps[f].is_finite() {
            let up_start = now.max(self.up_free[f]);
            self.up_free[f] = up_start + Self::tx_time(bytes, self.up_bps[f]);
        }
        self.charged += bytes;
    }

    /// Schedule `parts` under fault injection: consult the loss layer and
    /// either deliver (Some(delivery time)) or drop in flight (None). With
    /// no loss model installed this is byte- and draw-identical to
    /// [`NetworkFabric::transfer`]. `retransmit` tags the attempt for the
    /// ledger's goodput split.
    pub fn try_transfer(
        &mut self,
        now: SimTime,
        from: NodeId,
        to: NodeId,
        parts: &[(MsgKind, u64)],
        retransmit: bool,
    ) -> Option<SimTime> {
        if !self.loss.enabled() {
            let bytes: u64 = parts.iter().map(|(_, b)| b).sum();
            let plan = self.plan(now, from, to, bytes);
            self.ledger.record_attempt(from, to, parts, retransmit, true);
            return Some(plan.delivered);
        }
        self.ensure_nodes(from.max(to) as usize + 1);
        let (ft, tt) = (self.tier[from as usize], self.tier[to as usize]);
        if self.loss.decide(now, from as usize, to as usize, ft, tt) {
            let bytes: u64 = parts.iter().map(|(_, b)| b).sum();
            self.plan_dropped(now, from, bytes);
            self.ledger.record_attempt(from, to, parts, retransmit, false);
            return None;
        }
        let bytes: u64 = parts.iter().map(|(_, b)| b).sum();
        let plan = self.plan(now, from, to, bytes);
        self.ledger.record_attempt(from, to, parts, retransmit, true);
        Some(plan.delivered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_fabric(nodes: usize, bps: f64) -> NetworkFabric {
        let latency = LatencyMatrix::uniform(nodes, SimTime::from_millis(10));
        NetworkFabric::uniform(latency, bps, nodes)
    }

    #[test]
    fn single_transfer_is_latency_plus_tx() {
        let mut f = flat_fabric(4, 1e6); // 1 Mbit/s
        // 12_500 bytes = 100_000 bits -> 0.1 s at 1 Mbit/s.
        let p = f.plan(SimTime::ZERO, 0, 1, 12_500);
        assert_eq!(p.up_start, SimTime::ZERO);
        assert_eq!(p.up_end, SimTime::from_millis(100));
        assert_eq!(p.delivered, SimTime::from_millis(110));
    }

    #[test]
    fn concurrent_sends_serialize_on_uplink() {
        let mut f = flat_fabric(4, 1e6);
        let a = f.plan(SimTime::ZERO, 0, 1, 12_500);
        let b = f.plan(SimTime::ZERO, 0, 2, 12_500);
        // Second transfer queues behind the first on node 0's uplink.
        assert_eq!(b.up_start, a.up_end);
        assert_eq!(b.delivered, SimTime::from_millis(210));
    }

    #[test]
    fn concurrent_receives_serialize_on_downlink() {
        let mut f = flat_fabric(4, 1e6);
        let a = f.plan(SimTime::ZERO, 1, 0, 12_500);
        let b = f.plan(SimTime::ZERO, 2, 0, 12_500);
        assert_eq!(a.delivered, SimTime::from_millis(110));
        // b's downlink window starts only after a's ends.
        assert_eq!(b.delivered, SimTime::from_millis(210));
    }

    #[test]
    fn bottleneck_is_min_of_up_and_down() {
        let latency = LatencyMatrix::uniform(2, SimTime::ZERO);
        let bw = BandwidthConfig::PerNode {
            up_bps: vec![8e6, 1e6],
            down_bps: vec![1e6, 2e6],
        };
        let mut rng = SimRng::new(1);
        let mut f = NetworkFabric::new(latency, &bw, 2, &mut rng);
        // 0 -> 1: min(up0=8M, down1=2M) = 2M -> 1 MB takes 4 s.
        let p = f.plan(SimTime::ZERO, 0, 1, 1_000_000);
        assert_eq!(p.delivered, SimTime::from_secs_f64(4.0));
    }

    #[test]
    fn slow_sender_does_not_block_other_receives() {
        // Receiver 0 has a fast downlink; sender 1 a 10x-thinner uplink.
        let latency = LatencyMatrix::uniform(3, SimTime::ZERO);
        let bw = BandwidthConfig::PerNode {
            up_bps: vec![1e6, 1e5, 1e6],
            down_bps: vec![1e6; 3],
        };
        let mut rng = SimRng::new(5);
        let mut f = NetworkFabric::new(latency, &bw, 3, &mut rng);
        // Thin sender 1 starts a 1 Mbit upload: its uplink is busy 10 s,
        // but the receiver's downlink is only occupied 1 s.
        let a = f.plan(SimTime::ZERO, 1, 0, 125_000);
        assert_eq!(a.delivered, SimTime::from_secs_f64(10.0));
        assert_eq!(a.down_end, SimTime::from_secs_f64(1.0));
        // A fast sender arrives at ~2 s — not queued behind the slow
        // sender's whole upload.
        let b = f.plan(SimTime::from_secs_f64(0.5), 2, 0, 125_000);
        assert_eq!(b.delivered, SimTime::from_secs_f64(2.0));
    }

    #[test]
    fn zero_capacity_stalls_instead_of_teleporting() {
        // A 0 bps trace entry (dead link) pays the max-transfer cap; it
        // must not be mistaken for unlimited capacity.
        let latency = LatencyMatrix::uniform(2, SimTime::ZERO);
        let bw = BandwidthConfig::PerNode { up_bps: vec![0.0, 1e6], down_bps: vec![1e6, 1e6] };
        let mut rng = SimRng::new(1);
        let mut f = NetworkFabric::new(latency, &bw, 2, &mut rng);
        let p = f.plan(SimTime::ZERO, 0, 1, 100);
        assert_eq!(p.delivered, SimTime::from_secs_f64(3600.0));
    }

    #[test]
    fn unlimited_override_removes_tx_time() {
        let mut f = flat_fabric(3, 1e3); // pathologically thin
        f.set_unlimited(0);
        f.set_unlimited(1);
        let p = f.plan(SimTime::ZERO, 0, 1, 10_000_000);
        assert_eq!(p.delivered, SimTime::from_millis(10)); // latency only
    }

    #[test]
    fn unlimited_receiver_does_not_serialize_receives() {
        // A slow client mid-upload must not head-of-line-block a fast
        // client's upload to an unlimited-capacity server (§4.3 FedAvg).
        let mut f = flat_fabric(3, 1e6);
        f.set_unlimited(0);
        let a = f.plan(SimTime::ZERO, 1, 0, 12_500); // 100ms uplink tx
        let b = f.plan(SimTime::from_millis(1), 2, 0, 12_500);
        assert_eq!(a.delivered, SimTime::from_millis(110));
        // b overlaps a at the server instead of queueing behind it.
        assert_eq!(b.delivered, SimTime::from_millis(111));
    }

    #[test]
    fn unlimited_sender_does_not_serialize_sends() {
        let mut f = flat_fabric(4, 1e6);
        f.set_unlimited(0);
        let a = f.plan(SimTime::ZERO, 0, 1, 12_500);
        let b = f.plan(SimTime::ZERO, 0, 2, 12_500);
        // Both pushes are gated only by each receiver's downlink.
        assert_eq!(a.delivered, SimTime::from_millis(110));
        assert_eq!(b.delivered, SimTime::from_millis(110));
    }

    #[test]
    fn transfer_records_ledger_and_charges_equally() {
        let mut f = flat_fabric(3, 1e6);
        f.transfer(SimTime::ZERO, 0, 1, &[(MsgKind::ModelPayload, 900), (MsgKind::Control, 100)]);
        f.transfer(SimTime::ZERO, 1, 2, &[(MsgKind::Control, 50)]);
        assert_eq!(f.ledger().total(), 1050);
        assert_eq!(f.charged_bytes(), 1050);
        assert!(f.ledger().is_conserved());
    }

    #[test]
    fn ensure_nodes_samples_capacity_for_joiners() {
        let latency = LatencyMatrix::uniform(8, SimTime::ZERO);
        let bw = BandwidthConfig::LogNormal { median_bps: 10e6, sigma: 0.5 };
        let mut rng = SimRng::new(7);
        let mut f = NetworkFabric::new(latency, &bw, 2, &mut rng);
        f.ensure_nodes(6);
        assert_eq!(f.nodes(), 6);
        for n in 0..6u32 {
            assert!(f.up_bps(n) >= 1e6 && f.up_bps(n) <= 100e6, "{}", f.up_bps(n));
        }
    }

    #[test]
    fn lognormal_spreads_capacities() {
        let latency = LatencyMatrix::uniform(64, SimTime::ZERO);
        let bw = BandwidthConfig::LogNormal { median_bps: 10e6, sigma: 0.6 };
        let mut rng = SimRng::new(3);
        let f = NetworkFabric::new(latency, &bw, 64, &mut rng);
        let min = (0..64u32).map(|n| f.up_bps(n)).fold(f64::MAX, f64::min);
        let max = (0..64u32).map(|n| f.up_bps(n)).fold(0.0f64, f64::max);
        assert!(max / min > 2.0, "no spread: {min}..{max}");
    }

    #[test]
    fn classes_pick_among_tiers() {
        let latency = LatencyMatrix::uniform(32, SimTime::ZERO);
        let bw = BandwidthConfig::Classes(vec![
            BandwidthClass { weight: 1.0, up_bps: 5e6, down_bps: 20e6 },
            BandwidthClass { weight: 1.0, up_bps: 50e6, down_bps: 100e6 },
        ]);
        let mut rng = SimRng::new(11);
        let f = NetworkFabric::new(latency, &bw, 32, &mut rng);
        let slow = (0..32u32).filter(|&n| f.up_bps(n) == 5e6).count();
        let fast = (0..32u32).filter(|&n| f.up_bps(n) == 50e6).count();
        assert_eq!(slow + fast, 32);
        assert!(slow > 0 && fast > 0, "{slow} slow / {fast} fast");
    }

    #[test]
    #[should_panic(expected = "finite positive")]
    fn nan_class_weight_panics() {
        let latency = LatencyMatrix::uniform(4, SimTime::ZERO);
        let bw = BandwidthConfig::Classes(vec![
            BandwidthClass { weight: 1.0, up_bps: 1e6, down_bps: 1e6 },
            BandwidthClass { weight: f64::NAN, up_bps: 2e6, down_bps: 2e6 },
        ]);
        let mut rng = SimRng::new(1);
        let _ = NetworkFabric::new(latency, &bw, 4, &mut rng);
    }

    #[test]
    #[should_panic(expected = "finite positive")]
    fn negative_class_weight_panics() {
        let latency = LatencyMatrix::uniform(4, SimTime::ZERO);
        let bw = BandwidthConfig::Classes(vec![BandwidthClass {
            weight: -2.0,
            up_bps: 1e6,
            down_bps: 1e6,
        }]);
        let mut rng = SimRng::new(1);
        let _ = NetworkFabric::new(latency, &bw, 4, &mut rng);
    }

    #[test]
    fn snapshot_roundtrip_resumes_link_clocks_and_growth_stream() {
        use crate::sim::{SnapshotReader, SnapshotWriter};
        let bw = BandwidthConfig::LogNormal { median_bps: 10e6, sigma: 0.5 };
        let build = || {
            let latency = LatencyMatrix::uniform(16, SimTime::from_millis(5));
            let mut rng = SimRng::new(99);
            NetworkFabric::new(latency, &bw, 4, &mut rng)
        };
        let mut a = build();
        a.set_unlimited(1);
        a.transfer(SimTime::ZERO, 0, 1, &[(MsgKind::ModelPayload, 40_000)]);
        a.transfer(SimTime::from_millis(2), 2, 3, &[(MsgKind::Control, 500)]);
        a.ensure_nodes(7); // growth RNG consumed mid-session
        let mut w = SnapshotWriter::new();
        w.begin_section("fabric");
        a.write_into(&mut w);
        w.end_section();
        let bytes = w.finish();

        // Restore onto a freshly spec-built fabric, as the resume path does.
        let mut b = build();
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("fabric").unwrap();
        b.restore_from(&mut r).unwrap();
        r.end_section().unwrap();
        r.finish().unwrap();
        assert_eq!(b.nodes(), a.nodes());
        for n in 0..a.nodes() as u32 {
            assert_eq!(a.up_bps(n).to_bits(), b.up_bps(n).to_bits(), "node {n} up");
            assert_eq!(a.down_bps(n).to_bits(), b.down_bps(n).to_bits(), "node {n} down");
        }
        assert!(b.up_bps(1).is_infinite(), "unlimited override lost");
        assert_eq!(b.charged_bytes(), a.charged_bytes());
        assert_eq!(b.ledger().total(), a.ledger().total());
        assert_eq!(b.ledger().messages(), a.ledger().messages());
        // Identical future behaviour: FIFO clocks AND the growth stream
        // (a post-restore joiner must sample the same capacity).
        let pa = a.plan(SimTime::from_millis(3), 0, 3, 9_000);
        let pb = b.plan(SimTime::from_millis(3), 0, 3, 9_000);
        assert_eq!(pa.delivered, pb.delivered);
        assert_eq!(pa.up_start, pb.up_start);
        a.ensure_nodes(9);
        b.ensure_nodes(9);
        assert_eq!(a.up_bps(8).to_bits(), b.up_bps(8).to_bits(), "growth stream diverged");
    }

    #[test]
    fn try_transfer_without_loss_matches_transfer() {
        let mut a = flat_fabric(4, 1e6);
        let mut b = flat_fabric(4, 1e6);
        let parts = [(MsgKind::ModelPayload, 12_500u64)];
        let ta = a.transfer(SimTime::ZERO, 0, 1, &parts);
        let tb = b.try_transfer(SimTime::ZERO, 0, 1, &parts, false).unwrap();
        assert_eq!(ta, tb);
        assert_eq!(a.ledger().total(), b.ledger().total());
        assert_eq!(b.ledger().dropped_bytes(), 0);
        assert_eq!(b.ledger().goodput(), 12_500);
    }

    #[test]
    fn total_loss_drops_everything_but_still_charges_uplink() {
        let mut f = flat_fabric(3, 1e6);
        f.set_loss(LossModel::Uniform { p: 1.0 }, SimRng::new(1).fork("loss"));
        assert!(f.has_loss());
        let parts = [(MsgKind::ModelPayload, 12_500u64)];
        assert!(f.try_transfer(SimTime::ZERO, 0, 1, &parts, false).is_none());
        assert!(f.try_transfer(SimTime::ZERO, 0, 2, &parts, false).is_none());
        assert_eq!(f.ledger().dropped_bytes(), 25_000);
        assert_eq!(f.ledger().goodput(), 0);
        assert_eq!(f.charged_bytes(), 25_000);
        assert!(f.ledger().is_conserved());
        // Both attempts serialized on node 0's uplink: a third, delivered
        // send must queue behind 200ms of occupancy.
        f.set_loss(LossModel::Uniform { p: 0.0 }, SimRng::new(1).fork("loss"));
        let at = f.try_transfer(SimTime::ZERO, 0, 1, &parts, false).unwrap();
        assert_eq!(at, SimTime::from_millis(310));
    }

    #[test]
    fn dropped_transfers_leave_receiver_downlink_idle() {
        let mut f = flat_fabric(3, 1e6);
        f.set_loss(LossModel::Uniform { p: 1.0 }, SimRng::new(2).fork("loss"));
        let parts = [(MsgKind::ModelPayload, 125_000u64)]; // 1s of occupancy
        assert!(f.try_transfer(SimTime::ZERO, 0, 1, &parts, false).is_none());
        f.set_loss(LossModel::Uniform { p: 0.0 }, SimRng::new(2).fork("loss"));
        // Node 2's send to the same receiver is not queued behind the
        // ghost of the dropped transfer.
        let at = f.try_transfer(SimTime::ZERO, 2, 1, &parts, false).unwrap();
        assert_eq!(at, SimTime::from_millis(1010));
    }

    #[test]
    fn classes_loss_uses_sampled_tiers() {
        let latency = LatencyMatrix::uniform(32, SimTime::ZERO);
        let bw = BandwidthConfig::Classes(vec![
            BandwidthClass { weight: 1.0, up_bps: 5e6, down_bps: 20e6 },
            BandwidthClass { weight: 1.0, up_bps: 50e6, down_bps: 100e6 },
        ]);
        let mut rng = SimRng::new(11);
        let mut f = NetworkFabric::new(latency, &bw, 32, &mut rng);
        // Tier indices line up with the sampled capacities.
        for n in 0..32u32 {
            let want = if f.up_bps(n) == 5e6 { 0 } else { 1 };
            assert_eq!(f.tier(n), want, "node {n}");
        }
        // Tier 0 lossless, tier 1 always drops: a transfer touching any
        // tier-1 endpoint dies, tier-0 pairs always deliver.
        f.set_loss(
            LossModel::Classes { tier_p: vec![0.0, 1.0] },
            SimRng::new(11).fork("loss"),
        );
        let slow: Vec<u32> = (0..32u32).filter(|&n| f.tier(n) == 0).collect();
        let fast: Vec<u32> = (0..32u32).filter(|&n| f.tier(n) == 1).collect();
        let parts = [(MsgKind::Control, 100u64)];
        assert!(f.try_transfer(SimTime::ZERO, slow[0], slow[1], &parts, false).is_some());
        assert!(f.try_transfer(SimTime::ZERO, slow[0], fast[0], &parts, false).is_none());
        assert!(f.try_transfer(SimTime::ZERO, fast[0], slow[0], &parts, false).is_none());
    }

    #[test]
    fn loss_rides_fabric_snapshots() {
        use crate::sim::{SnapshotReader, SnapshotWriter};
        let build = || {
            let latency = LatencyMatrix::uniform(8, SimTime::from_millis(5));
            let mut rng = SimRng::new(7);
            let mut f = NetworkFabric::new(
                latency,
                &BandwidthConfig::Uniform { bps: 1e6 },
                8,
                &mut rng,
            );
            f.set_loss(
                LossModel::Burst { p_good: 0.05, p_bad: 0.8, good_mean_s: 4.0, bad_mean_s: 1.0 },
                SimRng::new(7).fork("loss"),
            );
            f
        };
        let mut a = build();
        let parts = [(MsgKind::ModelPayload, 4_000u64)];
        for i in 0..200u64 {
            let t = SimTime::from_millis(i * 37);
            a.try_transfer(t, (i % 8) as u32, ((i + 3) % 8) as u32, &parts, false);
        }
        let mut w = SnapshotWriter::new();
        w.begin_section("fabric");
        a.write_into(&mut w);
        w.end_section();
        let bytes = w.finish();

        let mut b = build();
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("fabric").unwrap();
        b.restore_from(&mut r).unwrap();
        r.end_section().unwrap();
        r.finish().unwrap();
        assert_eq!(a.ledger().dropped_bytes(), b.ledger().dropped_bytes());
        // Identical future drop decisions: the loss RNG and every burst
        // channel resumed exactly.
        for i in 200..400u64 {
            let t = SimTime::from_millis(i * 37);
            let (from, to) = ((i % 8) as u32, ((i + 3) % 8) as u32);
            assert_eq!(
                a.try_transfer(t, from, to, &parts, false).is_some(),
                b.try_transfer(t, from, to, &parts, false).is_some(),
                "decision diverged at attempt {i}"
            );
        }
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let bw = BandwidthConfig::LogNormal { median_bps: 10e6, sigma: 0.4 };
        let build = || {
            let latency = LatencyMatrix::uniform(16, SimTime::ZERO);
            let mut rng = SimRng::new(42);
            NetworkFabric::new(latency, &bw, 16, &mut rng)
        };
        let a = build();
        let b = build();
        for n in 0..16u32 {
            assert_eq!(a.up_bps(n), b.up_bps(n));
            assert_eq!(a.down_bps(n), b.down_bps(n));
        }
    }
}
