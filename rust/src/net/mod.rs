//! WAN network substrate: latency matrix, contended per-node bandwidth,
//! transfer scheduling, and per-node traffic accounting.
//!
//! The paper delays application-layer traffic with RTTs measured between 227
//! WonderNetwork cities, assigns nodes to cities round-robin, and charges
//! transfers against per-node network capacities from realistic traces
//! (§4.2). We reproduce the structure with a seeded synthetic geography
//! ([`latency`]), a per-node uplink/downlink capacity model with FIFO link
//! contention ([`fabric`]), a wire-size model ([`message`]), and per-node
//! traffic accounting ([`traffic`]) — all reproducible from the session
//! seed. See DESIGN.md §3 for the substitution argument.

pub mod fabric;
pub mod latency;
pub mod loss;
pub mod message;
pub mod traffic;

pub use fabric::{BandwidthClass, BandwidthConfig, NetworkFabric, TransferPlan};
pub use latency::{LatencyMatrix, LatencyParams};
pub use loss::{LossLayer, LossModel};
pub use message::{MsgKind, SizeModel};
pub use traffic::TrafficLedger;
