//! WAN network substrate: latency matrix, transfer-time model, and per-node
//! traffic accounting.
//!
//! The paper delays application-layer traffic with RTTs measured between 227
//! WonderNetwork cities and assigns nodes to cities round-robin (§4.2). We
//! reproduce the structure with a seeded synthetic geography (cities on a
//! sphere, great-circle propagation delay at fiber speed + jitter) so the
//! matrix is reproducible from the session seed — see DESIGN.md §3 for the
//! substitution argument.

pub mod latency;
pub mod message;
pub mod traffic;

pub use latency::{LatencyMatrix, LatencyParams};
pub use message::{MsgKind, SizeModel};
pub use traffic::TrafficLedger;
