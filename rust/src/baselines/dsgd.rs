//! D-SGD baseline (§2, §4.3): every node trains every round and averages
//! with its one-peer exponential-graph neighbour.
//!
//! Event-driven over the same DES/network substrates as MoDeST: a node's
//! round `r` is (train locally) ∥ (receive neighbour model of round `r`),
//! then average the two and advance — the pairwise barrier of the one-peer
//! topology, with no global synchronization. Per the paper we do not charge
//! the cost of establishing/maintaining the topology.

use std::collections::HashMap;
use std::sync::Arc;

use crate::learning::{ComputeModel, Model, Task};
use crate::metrics::{SessionMetrics, TrafficSummary};
use crate::net::{LatencyMatrix, MsgKind, SizeModel, TrafficLedger};
use crate::sim::{EventQueue, SimRng, SimTime};
use crate::{NodeId, Round};

use super::topology::OnePeerExpGraph;

#[derive(Debug, Clone)]
pub struct DsgdConfig {
    pub max_time: SimTime,
    pub max_rounds: Round,
    pub eval_interval: SimTime,
    /// How many node models to evaluate for the mean±std curve (paper
    /// evaluates all; a subsample keeps wallclock sane at n=355).
    pub eval_nodes: usize,
    /// Evaluate the across-node average model instead of individual models
    /// (the paper does this for MovieLens).
    pub eval_avg_model: bool,
    pub target_metric: Option<f64>,
    pub seed: u64,
    pub bandwidth_bps: f64,
}

impl Default for DsgdConfig {
    fn default() -> Self {
        DsgdConfig {
            max_time: SimTime::from_secs_f64(1800.0),
            max_rounds: 0,
            eval_interval: SimTime::from_secs_f64(20.0),
            eval_nodes: 8,
            eval_avg_model: false,
            target_metric: None,
            seed: 42,
            bandwidth_bps: 50e6,
        }
    }
}

enum Event {
    TrainDone { node: NodeId, round: Round },
    Deliver { to: NodeId, round: Round, model: Arc<Model> },
    Probe,
}

struct DsgdNode {
    round: Round,
    model: Model,
    /// Own trained model for the current round, once finished.
    trained: Option<Model>,
    /// Early-arrived neighbour models per round.
    inbox: HashMap<Round, Arc<Model>>,
}

pub struct DsgdSession {
    cfg: DsgdConfig,
    graph: OnePeerExpGraph,
    queue: EventQueue<Event>,
    nodes: Vec<DsgdNode>,
    task: Box<dyn Task>,
    compute: ComputeModel,
    latency: LatencyMatrix,
    sizes: SizeModel,
    traffic: TrafficLedger,
    metrics: SessionMetrics,
    done: bool,
}

impl DsgdSession {
    pub fn new(
        cfg: DsgdConfig,
        n: usize,
        task: Box<dyn Task>,
        compute: ComputeModel,
        latency: LatencyMatrix,
    ) -> DsgdSession {
        let init = task.init_model();
        let nodes = (0..n)
            .map(|_| DsgdNode {
                round: 1,
                model: init.clone(),
                trained: None,
                inbox: HashMap::new(),
            })
            .collect();
        DsgdSession {
            cfg,
            graph: OnePeerExpGraph::new(n as u32),
            queue: EventQueue::new(),
            nodes,
            task,
            compute,
            latency,
            sizes: SizeModel::default(),
            traffic: TrafficLedger::new(n),
            metrics: SessionMetrics::default(),
            done: false,
        }
    }

    fn seed_for(&self, node: NodeId, round: Round) -> u64 {
        self.cfg
            .seed
            .wrapping_mul(0xA24BAED4963EE407)
            .wrapping_add((node as u64) << 32)
            .wrapping_add(round)
    }

    fn start_training(&mut self, node: NodeId) {
        let batches = self.task.batches_per_epoch(node);
        let dur = self.compute.train_time(node, batches);
        let round = self.nodes[node as usize].round;
        self.queue.schedule_in(dur, Event::TrainDone { node, round });
    }

    fn send_model(&mut self, from: NodeId, to: NodeId, round: Round, model: Arc<Model>) {
        let bytes = self.sizes.model_transfer_bytes(self.task.model_bytes(), 0);
        self.traffic
            .record_parts(from, to, &[(MsgKind::ModelPayload, self.task.model_bytes()), (MsgKind::Control, bytes - self.task.model_bytes())]);
        let transfer = SimTime::from_secs_f64(bytes as f64 * 8.0 / self.cfg.bandwidth_bps);
        let delay = self.latency.one_way(from, to) + transfer;
        self.queue.schedule_in(delay, Event::Deliver { to, round, model });
    }

    /// If node finished training and has its neighbour's model, average and
    /// move to the next round.
    fn try_advance(&mut self, node: NodeId) {
        let round = self.nodes[node as usize].round;
        let ready = {
            let n = &self.nodes[node as usize];
            n.trained.is_some() && n.inbox.contains_key(&round)
        };
        if !ready {
            return;
        }
        let (own, incoming) = {
            let n = &mut self.nodes[node as usize];
            (n.trained.take().unwrap(), n.inbox.remove(&round).unwrap())
        };
        let avg = self
            .task
            .aggregate(&[&own, incoming.as_ref()])
            .expect("aggregate");
        {
            let n = &mut self.nodes[node as usize];
            n.model = avg;
            n.round = round + 1;
            // Drop stale early arrivals of long-past rounds.
            n.inbox.retain(|&k, _| k >= round);
        }
        if node == 0 {
            self.metrics.record_round_start(round + 1, self.queue.now());
        }
        if self.cfg.max_rounds > 0 && round + 1 > self.cfg.max_rounds {
            self.done = true;
            return;
        }
        self.start_training(node);
    }

    fn handle_train_done(&mut self, node: NodeId, round: Round) {
        if self.nodes[node as usize].round != round {
            return; // stale
        }
        let seed = self.seed_for(node, round);
        let model = self.nodes[node as usize].model.clone();
        let (updated, _loss, _b) = self
            .task
            .local_update(&model, node, seed)
            .expect("local_update");
        let out = self.graph.out_neighbor(node, round);
        let arc = Arc::new(updated.clone());
        self.nodes[node as usize].trained = Some(updated);
        self.send_model(node, out, round, arc);
        self.try_advance(node);
    }

    fn handle_deliver(&mut self, to: NodeId, round: Round, model: Arc<Model>) {
        self.nodes[to as usize].inbox.insert(round, model);
        self.try_advance(to);
    }

    fn handle_probe(&mut self) {
        let n = self.nodes.len();
        let (metric, loss, std) = if self.cfg.eval_avg_model {
            let models: Vec<&Model> = self.nodes.iter().map(|x| &x.model).collect();
            let avg = self.task.aggregate(&models).expect("aggregate");
            let e = self.task.evaluate(&avg).expect("evaluate");
            (e.metric, e.loss, 0.0)
        } else {
            // Evaluate an even subsample of node models; report mean±std
            // like the paper's Fig. 3 D-SGD curves.
            let k = self.cfg.eval_nodes.min(n).max(1);
            let mut metrics = Vec::with_capacity(k);
            let mut losses = Vec::with_capacity(k);
            for j in 0..k {
                let idx = j * n / k;
                let model = self.nodes[idx].model.clone();
                let e = self.task.evaluate(&model).expect("evaluate");
                metrics.push(e.metric);
                losses.push(e.loss);
            }
            let mean = metrics.iter().sum::<f64>() / k as f64;
            let var = metrics.iter().map(|m| (m - mean).powi(2)).sum::<f64>() / k as f64;
            let loss = losses.iter().sum::<f64>() / k as f64;
            (mean, loss, var.sqrt())
        };
        let round = self.nodes.iter().map(|x| x.round).min().unwrap_or(0);
        self.metrics
            .record_eval(self.queue.now(), round, metric, loss, std);
        if let Some(target) = self.cfg.target_metric {
            let hit = if self.task.metric_is_accuracy() {
                metric >= target
            } else {
                metric <= target
            };
            if hit {
                self.done = true;
            }
        }
    }

    pub fn run(mut self) -> (SessionMetrics, TrafficLedger) {
        let _ = SimRng::new(self.cfg.seed); // reserved for future stochastic exts
        let mut t = self.cfg.eval_interval;
        while t <= self.cfg.max_time {
            self.queue.schedule_at(t, Event::Probe);
            t = t + self.cfg.eval_interval;
        }
        self.metrics.record_round_start(1, SimTime::ZERO);
        for node in 0..self.nodes.len() as NodeId {
            self.start_training(node);
        }
        // Baseline evaluation of the initial model at t=0.
        self.handle_probe();
        while let Some((now, ev)) = self.queue.pop() {
            if now > self.cfg.max_time || self.done {
                break;
            }
            match ev {
                Event::TrainDone { node, round } => self.handle_train_done(node, round),
                Event::Deliver { to, round, model } => self.handle_deliver(to, round, model),
                Event::Probe => self.handle_probe(),
            }
        }
        // Terminal evaluation so short sessions still produce a curve.
        self.handle_probe();
        self.metrics.final_round = self.nodes.iter().map(|n| n.round).min().unwrap_or(0);
        self.metrics.duration_s = self.queue.now().as_secs_f64();
        self.metrics.events = self.queue.events_processed();
        self.metrics.traffic = TrafficSummary::from_ledger(&self.traffic, self.nodes.len());
        (self.metrics, self.traffic)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::MockTask;
    use crate::net::LatencyParams;

    fn session(n: usize, cfg: DsgdConfig) -> DsgdSession {
        let mut rng = SimRng::new(cfg.seed);
        let task = MockTask::new(n, 16, 0.5, cfg.seed);
        let latency =
            LatencyMatrix::synthetic(&LatencyParams::default(), n, &mut rng.fork("lat"));
        let compute = ComputeModel::uniform(n, 0.05);
        DsgdSession::new(cfg, n, Box::new(task), compute, latency)
    }

    #[test]
    fn all_nodes_advance_and_converge() {
        let cfg = DsgdConfig {
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 40,
            eval_interval: SimTime::from_secs_f64(5.0),
            ..Default::default()
        };
        let (m, traffic) = session(8, cfg).run();
        eprintln!(
            "dsgd: final_round={} best={:?} msgs={}",
            m.final_round,
            m.best_metric(true),
            traffic.messages()
        );
        assert!(m.final_round >= 30, "round {}", m.final_round);
        // D-SGD carries residual variance between local models (the
        // paper's central observation), so the bar is lower than the
        // MoDeST session test's 0.8.
        assert!(m.best_metric(true).unwrap() > 0.4, "best {:?}", m.best_metric(true));
        assert!(traffic.is_conserved());
    }

    #[test]
    fn traffic_is_evenly_balanced() {
        let cfg = DsgdConfig {
            max_time: SimTime::from_secs_f64(300.0),
            max_rounds: 20,
            ..Default::default()
        };
        let (_, traffic) = session(8, cfg).run();
        let (min, max) = traffic.min_max_usage(8);
        // Every node sends/receives exactly one model per round: near-equal.
        assert!(
            (max as f64) < 1.2 * (min as f64),
            "imbalanced D-SGD: {min} vs {max}"
        );
    }

    #[test]
    fn every_node_participates_every_round() {
        let cfg = DsgdConfig {
            max_time: SimTime::from_secs_f64(200.0),
            max_rounds: 10,
            ..Default::default()
        };
        let (m, traffic) = session(6, cfg).run();
        // 6 nodes x >= 9 completed rounds x 1 model message each (the
        // session stops as soon as any node would enter round 11, so the
        // final round's tail messages may not all be sent).
        assert!(traffic.messages() >= 54, "{}", traffic.messages());
        assert!(m.final_round >= 10);
    }
}
