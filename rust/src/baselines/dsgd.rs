//! D-SGD baseline (§2, §4.3): every node trains every round and averages
//! with its one-peer exponential-graph neighbour.
//!
//! Implemented as a [`Protocol`] over the shared [`SimHarness`] — the same
//! DES kernel and [`NetworkFabric`] MoDeST runs on: a node's round `r` is
//! (train locally) ∥ (receive neighbour model of round `r`), then average
//! the two and advance — the pairwise barrier of the one-peer topology,
//! with no global synchronization. Per the paper we do not charge the cost
//! of establishing/maintaining the topology.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::learning::{ComputeModel, Model, Task};
use crate::metrics::SessionMetrics;
use crate::net::{MsgKind, NetworkFabric, SizeModel, TrafficLedger};
use crate::runtime::XlaRuntime;
use crate::scenario::{ProtocolMeta, ScenarioSpec, Session, SessionBuilder};
use crate::sim::{
    ChurnEvent, ChurnKind, ChurnSchedule, Ctx, EvalPoint, HarnessConfig, LivenessMirror,
    NodeTable, Protocol, ReliabilityConfig, ReliableOutbox, ResumeOptions, SamplingVersion,
    SimHarness, SimTime, SnapshotReader, SnapshotWriter, TimerVerdict,
};
use crate::{NodeId, Round};

use super::topology::OnePeerExpGraph;

/// D-SGD parameters. Bandwidth is no longer here: per-node capacities
/// belong to the [`NetworkFabric`].
#[derive(Debug, Clone)]
pub struct DsgdConfig {
    pub max_time: SimTime,
    pub max_rounds: Round,
    pub eval_interval: SimTime,
    /// How many node models to evaluate for the mean±std curve (paper
    /// evaluates all; a subsample keeps wallclock sane at n=355).
    pub eval_nodes: usize,
    /// Evaluate the across-node average model instead of individual models
    /// (the paper does this for MovieLens).
    pub eval_avg_model: bool,
    pub target_metric: Option<f64>,
    pub seed: u64,
    /// Peer-sampling stream version. D-SGD itself samples no peers (fixed
    /// topology), but the harness plumbing carries the session-wide choice.
    pub sampling: SamplingVersion,
    /// Canonical scenario JSON embedded into snapshots (None = session not
    /// built from a spec; checkpointing disabled).
    pub spec_json: Option<String>,
    /// Write a snapshot and stop once the clock reaches this instant.
    pub checkpoint_at: Option<SimTime>,
    /// Snapshot file path for `checkpoint_at`.
    pub checkpoint_out: Option<String>,
    /// Ack/timeout/retransmit contract; `Some` exactly when the session's
    /// network is lossy. `None` keeps every send a plain fire-and-forget
    /// [`Ctx::send`] with zero extra events or state.
    pub reliability: Option<ReliabilityConfig>,
    /// Live JSONL progress stream (None = off).
    pub progress: Option<crate::sim::ProgressConfig>,
    /// Event-queue execution threads (1 = classic single-threaded loop;
    /// T > 1 runs the sharded conservative-window scheduler, bit-identical).
    pub threads: usize,
}

impl Default for DsgdConfig {
    fn default() -> Self {
        DsgdConfig {
            max_time: SimTime::from_secs_f64(1800.0),
            max_rounds: 0,
            eval_interval: SimTime::from_secs_f64(20.0),
            eval_nodes: 8,
            eval_avg_model: false,
            target_metric: None,
            seed: 42,
            sampling: SamplingVersion::default(),
            spec_json: None,
            checkpoint_at: None,
            checkpoint_out: None,
            reliability: None,
            progress: None,
            threads: 1,
        }
    }
}

impl DsgdConfig {
    /// The harness plumbing derived from this config.
    pub fn harness_config(&self) -> HarnessConfig {
        HarnessConfig {
            max_time: self.max_time,
            max_rounds: self.max_rounds,
            eval_interval: self.eval_interval,
            target_metric: self.target_metric,
            seed: self.seed,
            sampling: self.sampling,
            spec_json: self.spec_json.clone(),
            checkpoint_at: self.checkpoint_at,
            checkpoint_out: self.checkpoint_out.clone(),
            progress: self.progress.clone(),
            threads: self.threads,
        }
    }
}

/// Timer ids with this bit set are barrier backstops: the low bits carry
/// the round whose pairwise barrier the node was stuck on. Disjoint from
/// [`crate::sim::RELIABLE_TIMER_BIT`] (bit 63), which the shared outbox
/// owns.
const DSGD_BACKSTOP_BIT: u64 = 1 << 62;

/// D-SGD wire messages: a neighbour's trained model for a round, and —
/// under a lossy network — the ack closing the reliable-delivery loop.
/// `seq == 0` marks an untracked (lossless-session) model send.
#[derive(Clone)]
pub enum DsgdMsg {
    Model { seq: u64, from: NodeId, round: Round, model: Arc<Model> },
    Ack { seq: u64 },
}

/// The D-SGD state machine (drives through [`SimHarness`]).
pub struct DsgdProtocol {
    cfg: DsgdConfig,
    graph: OnePeerExpGraph,
    /// Hot per-node counters in SoA columns:
    /// * `rounds` — the per-node training round;
    /// * `seqs` — monotone training sequence, bumped at every
    ///   `start_training` and at recovery. Completions carry it, so a
    ///   pre-crash in-flight completion cannot be mistaken for
    ///   post-recovery training when the rejoin round equals the
    ///   crash-time round (the node must not "train through" its own
    ///   downtime);
    /// * `epochs` — the round the node jumped to when it last recovered
    ///   from a crash (0 = never recovered). Rounds below it were skipped
    ///   while dead: the node never trains them, so an out-neighbour's
    ///   pairwise barrier must not wait on them, and the recovery round
    ///   itself runs barrier-free (the in-neighbour's model for it may
    ///   have been dropped at the dead node).
    nodes: NodeTable,
    /// Cold per-node state, parallel to the columns above.
    models: Vec<Model>,
    /// Own trained model for the current round, once finished.
    trained: Vec<Option<Model>>,
    /// Early-arrived neighbour models per round.
    inboxes: Vec<HashMap<Round, Arc<Model>>>,
    /// Liveness mirror for churn tolerance: a node whose in-neighbour died
    /// advances without the dead trainer's model instead of deadlocking on
    /// the pairwise barrier. Shared bookkeeping with gossip-DL (recorder
    /// handoff, monotone round trace, live-filtered evaluation).
    live: LivenessMirror,
    /// Highest round any node has reached through actual barrier
    /// advancement (monotone, updated in `try_advance` only — recovery
    /// rejoins read it but never bump it, so repeated Recover events
    /// cannot inflate it past real training progress). Gives recovery an
    /// O(1) rejoin target instead of an O(n) live-frontier scan per
    /// Recover event.
    top_round: Round,
    sizes: SizeModel,
    /// Retransmit ledger for model sends; `Some` exactly in lossy sessions.
    outbox: Option<ReliableOutbox<DsgdMsg>>,
    /// Per-node round whose pairwise barrier was waived by a fired
    /// backstop (0 = none): the in-neighbour's model never landed within
    /// the full retransmit window, so the node aggregates without it
    /// instead of deadlocking. Only ever set in lossy sessions.
    waived: Vec<Round>,
}

impl DsgdProtocol {
    fn seed_for(&self, node: NodeId, round: Round) -> u64 {
        self.cfg
            .seed
            .wrapping_mul(0xA24BAED4963EE407)
            .wrapping_add((node as u64) << 32)
            .wrapping_add(round)
    }

    fn start_training(&mut self, ctx: &mut Ctx<'_, DsgdMsg>, node: NodeId) {
        let batches = ctx.task.batches_per_epoch(node);
        let dur = ctx.compute.train_time(node, batches);
        // A fresh sequence id per training job: exactly one completion is
        // ever valid, and recovery invalidates in-flight pre-crash jobs by
        // bumping past them (the round alone cannot, since a rejoin may
        // land on the crash-time round number).
        let seq = self.nodes.bump_seq(node as usize);
        ctx.schedule_train_done(dur, node, seq);
    }

    fn send_model(
        &mut self,
        ctx: &mut Ctx<'_, DsgdMsg>,
        from: NodeId,
        to: NodeId,
        round: Round,
        model: Arc<Model>,
    ) {
        let model_b = ctx.task.model_bytes();
        let total = self.sizes.model_transfer_bytes(model_b, 0);
        let parts = [(MsgKind::ModelPayload, model_b), (MsgKind::Control, total - model_b)];
        match &mut self.outbox {
            Some(ob) => {
                ob.track(ctx, from, to, &parts, |seq| DsgdMsg::Model {
                    seq,
                    from,
                    round,
                    model,
                });
            }
            None => ctx.send(from, to, &parts, DsgdMsg::Model { seq: 0, from, round, model }),
        }
    }

    /// If node finished training and has its neighbour's model (or that
    /// neighbour is dead or skipped this round while crashed — skip the
    /// missing trainer), average and move to the next round.
    fn try_advance(&mut self, ctx: &mut Ctx<'_, DsgdMsg>, node: NodeId) {
        let i = node as usize;
        let round = self.nodes.round(i);
        let in_nb = self.graph.in_neighbor(node, round) as usize;
        // The round's model can never arrive when the in-neighbour is
        // dead, or recovered past this round (it skipped it while down),
        // or when this IS the node's own barrier-free recovery round (its
        // in-neighbour may have sent while this node was dead — dropped).
        let never_arrives = self.live.is_dead(in_nb)
            || (in_nb < self.nodes.len() && self.nodes.epoch(in_nb) > round)
            || self.nodes.epoch(i) == round
            || self.waived[i] == round;
        let ready =
            self.trained[i].is_some() && (self.inboxes[i].contains_key(&round) || never_arrives);
        if !ready {
            return;
        }
        let own = self.trained[i].take().unwrap();
        let incoming = self.inboxes[i].remove(&round);
        let avg = match &incoming {
            Some(inc) => ctx.task.aggregate(&[&own, inc.as_ref()]).expect("aggregate"),
            // The round's in-neighbour crashed before its model arrived:
            // proceed with the local model alone.
            None => own,
        };
        self.models[i] = avg;
        self.nodes.set_round(i, round + 1);
        // Drop stale early arrivals of long-past rounds.
        self.inboxes[i].retain(|&k, _| k >= round);
        self.top_round = self.top_round.max(round + 1);
        // Record from the lowest live node (node 0 unless churn killed it),
        // keeping the round trace monotone across recorder handoffs.
        if self.live.should_record(node, round + 1) {
            ctx.record_round_start(round + 1);
        }
        if ctx.round_budget_exceeded(round + 1) {
            ctx.finish();
            return;
        }
        self.start_training(ctx, node);
    }
}

impl Protocol for DsgdProtocol {
    type Msg = DsgdMsg;

    fn bootstrap(&mut self, ctx: &mut Ctx<'_, DsgdMsg>) {
        ctx.record_round_start(1);
        self.live.force_started(1);
        for node in 0..self.nodes.len() as NodeId {
            self.start_training(ctx, node);
        }
    }

    fn on_deliver(&mut self, ctx: &mut Ctx<'_, DsgdMsg>, to: NodeId, msg: DsgdMsg) {
        match msg {
            DsgdMsg::Model { seq, from, round, model } => {
                // Duplicate deliveries (a retransmit raced the ack)
                // re-insert the same round model — idempotent — and re-ack,
                // because the first ack may itself have been dropped.
                self.inboxes[to as usize].insert(round, model);
                if seq != 0 {
                    ctx.send(
                        to,
                        from,
                        &[(MsgKind::Control, self.sizes.ping_bytes())],
                        DsgdMsg::Ack { seq },
                    );
                }
                self.try_advance(ctx, to);
            }
            DsgdMsg::Ack { seq } => {
                if let Some(ob) = &mut self.outbox {
                    ob.ack(seq);
                }
            }
        }
    }

    fn on_train_done(&mut self, ctx: &mut Ctx<'_, DsgdMsg>, node: NodeId, seq: u64) {
        if self.nodes.seq(node as usize) != seq {
            return; // stale (a newer job superseded it, or recovery did)
        }
        // The node's round cannot have moved since this job was scheduled
        // (advancing requires taking this very completion's `trained`), so
        // it is the round the training was for.
        let round = self.nodes.round(node as usize);
        let seed = self.seed_for(node, round);
        let model = self.models[node as usize].clone();
        let (updated, _loss, _b) =
            ctx.task.local_update(&model, node, seed).expect("local_update");
        let out = self.graph.out_neighbor(node, round);
        let arc = Arc::new(updated.clone());
        self.trained[node as usize] = Some(updated);
        if !self.live.is_dead(out as usize) {
            self.send_model(ctx, node, out, round, arc);
        }
        // Lossy sessions arm a barrier backstop: if the in-neighbour's
        // round model still hasn't landed once its full retransmit window
        // (plus one max deadline of margin for training skew) has passed,
        // the barrier is waived rather than deadlocked. Armed
        // unconditionally — a fired backstop for an already-advanced round
        // is recognised as stale and ignored.
        if let Some(ob) = &self.outbox {
            let delay = ob.cfg().expiry_window() + ob.cfg().max_timeout;
            ctx.schedule_timer(delay, node, DSGD_BACKSTOP_BIT | round);
        }
        self.try_advance(ctx, node);
    }

    fn on_timer(&mut self, ctx: &mut Ctx<'_, DsgdMsg>, node: NodeId, id: u64) {
        if let Some(ob) = &mut self.outbox {
            match ob.on_timer(ctx, id) {
                // Expiry needs no sender-side action: the degradation is
                // the receiver's backstop, which waives the barrier.
                TimerVerdict::Handled | TimerVerdict::Expired(_) => return,
                TimerVerdict::NotOurs => {}
            }
        }
        if id & DSGD_BACKSTOP_BIT != 0 {
            let round = id & !DSGD_BACKSTOP_BIT;
            let i = node as usize;
            if self.live.is_dead(i) || self.nodes.round(i) != round {
                return; // stale: the barrier already cleared
            }
            if self.inboxes[i].contains_key(&round) {
                return; // the model landed; the normal path owns the advance
            }
            self.waived[i] = round;
            self.try_advance(ctx, node);
        }
    }

    fn on_churn(&mut self, ctx: &mut Ctx<'_, DsgdMsg>, ev: ChurnEvent) {
        let i = ev.node as usize;
        if i >= self.nodes.len() {
            return;
        }
        match ev.kind {
            ChurnKind::Leave | ChurnKind::Crash => {
                self.live.set_dead(i);
                // Unblock the nodes whose pairwise barrier was waiting on
                // the dead trainer's model. Only a node whose CURRENT
                // round's in-neighbour is `i` can be newly unblocked (the
                // death flips exactly the `is_dead` term of its barrier
                // condition), and those all sit among the <= tau distinct
                // out-neighbours of `i` — an O(log n) candidate set
                // instead of a full-table sweep, which matters when
                // availability schedules emit crashes by the tens of
                // thousands. Ascending id order replays the old full
                // sweep's action order exactly (advancements within one
                // sweep cannot unblock each other — their sends are
                // future deliveries), so event order is unchanged.
                let mut waiters: Vec<NodeId> = (1..=self.graph.degree() as Round)
                    .map(|r| self.graph.out_neighbor(ev.node, r))
                    .collect();
                waiters.sort_unstable();
                waiters.dedup();
                for v in waiters {
                    if v as usize != i && !self.live.is_dead(v as usize) {
                        self.try_advance(ctx, v);
                    }
                }
            }
            // Recovery of a previously-crashed node (availability churn):
            // rejoin the fixed topology AT the current training frontier
            // (`top_round`, the highest round any node has reached). The
            // rejoin round itself is barrier-free (`resumed_at`), so the
            // node never waits on a round model that was dropped while it
            // was dead, and nobody waits on the rounds it skipped; from
            // the next round it is in lockstep with the frontier. Because
            // the target is the frontier — not one past it — recovery
            // never raises `top_round`, so periodic availability churn
            // cannot ratchet rounds toward `max_rounds` faster than real
            // training does. No try_advance sweep is needed here: every
            // waiter whose in-neighbour is `i` was already unblocked when
            // `i` crashed (the Crash arm's sweep, or its own
            // `on_train_done`'s dead-skip). Fresh joiner ids are still
            // rejected at build time — the one-peer exponential graph is
            // fixed at n nodes; a Join reaching here for a known id
            // behaves as a recovery.
            ChurnKind::Join | ChurnKind::Recover => {
                if !self.live.is_dead(i) {
                    return;
                }
                self.live.set_live(i);
                let rejoin = self.top_round.max(self.nodes.round(i));
                self.nodes.set_round(i, rejoin);
                self.nodes.set_epoch(i, rejoin);
                self.trained[i] = None;
                // Invalidate any pre-crash in-flight completion even
                // when the rejoin round equals the crash-time round.
                self.nodes.bump_seq(i);
                self.inboxes[i].retain(|&k, _| k >= rejoin);
                if !ctx.round_budget_exceeded(rejoin) {
                    self.start_training(ctx, ev.node);
                }
            }
        }
    }

    fn evaluate(&mut self, task: &mut dyn Task) -> Result<EvalPoint> {
        // Dead replicas are frozen at their crash-time model; evaluation
        // covers live nodes only (identical to the original when no churn).
        let live = self.live.live_indices();
        let n = live.len().max(1);
        let (metric, loss, std) = if self.cfg.eval_avg_model {
            let models: Vec<&Model> = live.iter().map(|&i| &self.models[i]).collect();
            let avg = if models.is_empty() {
                self.models[0].clone()
            } else {
                task.aggregate(&models)?
            };
            let e = task.evaluate(&avg)?;
            (e.metric, e.loss, 0.0)
        } else {
            // Evaluate an even subsample of node models; report mean±std
            // like the paper's Fig. 3 D-SGD curves.
            let k = self.cfg.eval_nodes.min(n).max(1);
            let mut metrics = Vec::with_capacity(k);
            let mut losses = Vec::with_capacity(k);
            for j in 0..k {
                let idx = live.get(j * n / k).copied().unwrap_or(0);
                let model = self.models[idx].clone();
                let e = task.evaluate(&model)?;
                metrics.push(e.metric);
                losses.push(e.loss);
            }
            let mean = metrics.iter().sum::<f64>() / k as f64;
            let var = metrics.iter().map(|m| (m - mean).powi(2)).sum::<f64>() / k as f64;
            let loss = losses.iter().sum::<f64>() / k as f64;
            (mean, loss, var.sqrt())
        };
        let round = self.final_round();
        Ok(EvalPoint { round, metric, loss, metric_std: std })
    }

    fn final_round(&self) -> Round {
        self.live.min_live_round(self.nodes.rounds())
    }

    // Dynamic state only: `cfg`, `graph` (fixed topology), and `sizes` are
    // rebuilt from the spec. Inbox maps are written in sorted round order so
    // iteration order never leaks into the bytes (HashMap order is seeded
    // per process); inbox models go through Arc interning.
    fn snapshot(&self, w: &mut SnapshotWriter) -> Result<()> {
        self.nodes.write_into(w);
        w.write_usize(self.models.len());
        for m in &self.models {
            w.write_model_plain(m);
        }
        w.write_usize(self.trained.len());
        for t in &self.trained {
            match t {
                Some(m) => {
                    w.write_bool(true);
                    w.write_model_plain(m);
                }
                None => w.write_bool(false),
            }
        }
        w.write_usize(self.inboxes.len());
        for inbox in &self.inboxes {
            let mut rounds: Vec<Round> = inbox.keys().copied().collect();
            rounds.sort_unstable();
            w.write_usize(rounds.len());
            for r in rounds {
                w.write_u64(r);
                w.write_model(&inbox[&r]);
            }
        }
        self.live.write_into(w);
        w.write_u64(self.top_round);
        w.write_usize(self.waived.len());
        for &r in &self.waived {
            w.write_u64(r);
        }
        w.write_bool(self.outbox.is_some());
        if let Some(ob) = &self.outbox {
            ob.write_into(w, |w, m| self.write_msg(w, m))?;
        }
        Ok(())
    }

    fn restore(&mut self, r: &mut SnapshotReader) -> Result<()> {
        self.nodes = NodeTable::read_from(r)?;
        let n = r.read_usize()?;
        let mut models = Vec::with_capacity(n);
        for _ in 0..n {
            models.push(r.read_model_plain()?);
        }
        self.models = models;
        let n = r.read_usize()?;
        let mut trained = Vec::with_capacity(n);
        for _ in 0..n {
            trained.push(if r.read_bool()? { Some(r.read_model_plain()?) } else { None });
        }
        self.trained = trained;
        let n = r.read_usize()?;
        let mut inboxes = Vec::with_capacity(n);
        for _ in 0..n {
            let k = r.read_usize()?;
            let mut inbox = HashMap::with_capacity(k);
            for _ in 0..k {
                let round = r.read_u64()?;
                inbox.insert(round, r.read_model()?);
            }
            inboxes.push(inbox);
        }
        self.inboxes = inboxes;
        self.live = LivenessMirror::read_from(r)?;
        self.top_round = r.read_u64()?;
        let n = r.read_usize()?;
        let mut waived = Vec::with_capacity(n);
        for _ in 0..n {
            waived.push(r.read_u64()?);
        }
        self.waived = waived;
        // Tolerate a loss-config overlay flip across the checkpoint: a
        // snapshot taken lossy restores into a lossless session by reading
        // and discarding the ledger; the reverse keeps the fresh outbox.
        if r.read_bool()? {
            let cfg = self.cfg.reliability.unwrap_or(ReliabilityConfig {
                timeout: SimTime::from_secs_f64(1.0),
                backoff: 1.0,
                max_timeout: SimTime::from_secs_f64(1.0),
                retries: 1,
            });
            let ob = ReliableOutbox::read_from(r, cfg, |r| self.read_msg(r))?;
            if self.cfg.reliability.is_some() {
                self.outbox = Some(ob);
            }
        }
        Ok(())
    }

    fn write_msg(&self, w: &mut SnapshotWriter, msg: &DsgdMsg) -> Result<()> {
        match msg {
            DsgdMsg::Model { seq, from, round, model } => {
                w.write_u8(0);
                w.write_u64(*seq);
                w.write_u32(*from);
                w.write_u64(*round);
                w.write_model(model);
            }
            DsgdMsg::Ack { seq } => {
                w.write_u8(1);
                w.write_u64(*seq);
            }
        }
        Ok(())
    }

    fn read_msg(&self, r: &mut SnapshotReader) -> Result<DsgdMsg> {
        match r.read_u8()? {
            0 => Ok(DsgdMsg::Model {
                seq: r.read_u64()?,
                from: r.read_u32()?,
                round: r.read_u64()?,
                model: r.read_model()?,
            }),
            1 => Ok(DsgdMsg::Ack { seq: r.read_u64()? }),
            t => anyhow::bail!("unknown d-sgd message tag {t}"),
        }
    }
}

/// Assembly facade: builds a [`DsgdProtocol`] and its [`SimHarness`].
pub struct DsgdSession {
    harness: SimHarness<DsgdProtocol>,
}

impl DsgdSession {
    /// Build a session over `n` nodes. The churn script may crash/leave
    /// (and is validated by the builder to contain nothing else — the
    /// fixed topology cannot admit joiners).
    pub fn new(
        cfg: DsgdConfig,
        n: usize,
        task: Box<dyn Task>,
        compute: ComputeModel,
        fabric: NetworkFabric,
        churn: ChurnSchedule,
    ) -> DsgdSession {
        let init = task.init_model();
        let nodes = NodeTable::new(n).with_rounds(1).with_seqs().with_epochs();
        let models = (0..n).map(|_| init.clone()).collect();
        let trained = (0..n).map(|_| None).collect();
        let inboxes = (0..n).map(|_| HashMap::new()).collect();
        let hcfg = cfg.harness_config();
        let outbox = cfg.reliability.map(ReliableOutbox::new);
        let protocol = DsgdProtocol {
            cfg,
            graph: OnePeerExpGraph::new(n as u32),
            nodes,
            models,
            trained,
            inboxes,
            live: LivenessMirror::all_live(n),
            top_round: 1,
            sizes: SizeModel::default(),
            outbox,
            waived: vec![0; n],
        };
        DsgdSession {
            harness: SimHarness::new(hcfg, protocol, n, n, task, compute, fabric, churn),
        }
    }

    pub fn run(self) -> (SessionMetrics, TrafficLedger) {
        self.harness.run()
    }
}

impl Session for DsgdSession {
    fn run(self: Box<Self>) -> (SessionMetrics, TrafficLedger) {
        DsgdSession::run(*self)
    }

    fn snapshot_bytes(&self) -> Result<Vec<u8>> {
        self.harness.snapshot_bytes()
    }

    fn resume(&mut self, r: &mut SnapshotReader, opts: &ResumeOptions) -> Result<()> {
        self.harness.restore_from(r, opts)
    }
}

/// Derive the D-SGD protocol config from a scenario spec.
pub fn dsgd_config(spec: &ScenarioSpec) -> DsgdConfig {
    DsgdConfig {
        max_time: SimTime::from_secs_f64(spec.run.max_time_s),
        max_rounds: spec.run.max_rounds,
        eval_interval: SimTime::from_secs_f64(spec.run.eval_interval_s),
        // Evaluating individual node models is the D-SGD probe cost;
        // 4 models keeps big-model probes affordable.
        eval_nodes: 4,
        eval_avg_model: spec.workload.dataset == "movielens",
        target_metric: spec.run.target_metric,
        seed: spec.run.seed,
        sampling: spec.run.sampling,
        spec_json: Some(spec.snapshot_json()),
        checkpoint_at: spec.run.checkpoint_at_s.map(SimTime::from_secs_f64),
        checkpoint_out: spec.run.checkpoint_out.clone(),
        reliability: spec.network.reliability(),
        progress: None,
        threads: spec.run.threads,
    }
}

/// Registry factory for D-SGD.
pub struct DsgdBuilder;

impl SessionBuilder for DsgdBuilder {
    fn meta(&self) -> ProtocolMeta {
        ProtocolMeta {
            name: "dsgd",
            label: "D-SGD",
            aliases: &["d-sgd", "dl"],
            summary: "decentralized SGD over a one-peer exponential graph: \
                      every node trains and averages pairwise every round",
            // D-SGD trains every node every round, so figure drivers cap it
            // lower — its convergence lag is visible well before 120 rounds.
            default_round_budget: 120,
            default_params: &[],
        }
    }

    fn build(
        &self,
        spec: &ScenarioSpec,
        runtime: Option<&XlaRuntime>,
        churn: ChurnSchedule,
    ) -> Result<Box<dyn Session>> {
        let n = spec.resolved_nodes()?;
        // Crashes, graceful leaves, and recoveries are tolerated (the
        // pairwise barrier skips dead or round-skipping trainers); joins
        // of fresh ids are not — the one-peer exponential graph is fixed
        // at n nodes. This admits availability-compiled schedules, which
        // emit only Crash/Recover over the initial population.
        for e in churn.events() {
            anyhow::ensure!(
                matches!(e.kind, ChurnKind::Crash | ChurnKind::Leave | ChurnKind::Recover),
                "d-sgd supports only crash/leave/recover churn (its fixed \
                 one-peer topology cannot admit fresh joiners)"
            );
            anyhow::ensure!(
                (e.node as usize) < n,
                "d-sgd churn names node {} outside the fixed population of {n}",
                e.node
            );
        }
        let task = spec.build_task(runtime)?;
        let fabric = spec.build_fabric(n)?;
        let compute = spec.build_compute(n);
        // `dsgd_config` is infallible; the fallible progress validation
        // happens here at the spec boundary, like the other builders.
        let mut cfg = dsgd_config(spec);
        cfg.progress = spec.progress_config()?;
        Ok(Box::new(DsgdSession::new(cfg, n, task, compute, fabric, churn)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::MockTask;
    use crate::net::{BandwidthConfig, LatencyMatrix, LatencyParams};
    use crate::sim::SimRng;

    fn session_with_churn(n: usize, cfg: DsgdConfig, churn: ChurnSchedule) -> DsgdSession {
        let mut rng = SimRng::new(cfg.seed);
        let task = MockTask::new(n, 16, 0.5, cfg.seed);
        let latency = LatencyMatrix::synthetic(&LatencyParams::default(), n, &mut rng.fork("lat"));
        let fabric =
            NetworkFabric::new(latency, &BandwidthConfig::uniform_mbps(50.0), n, &mut rng.fork("bw"));
        let compute = ComputeModel::uniform(n, 0.05);
        DsgdSession::new(cfg, n, Box::new(task), compute, fabric, churn)
    }

    fn session(n: usize, cfg: DsgdConfig) -> DsgdSession {
        session_with_churn(n, cfg, ChurnSchedule::empty())
    }

    #[test]
    fn all_nodes_advance_and_converge() {
        let cfg = DsgdConfig {
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 40,
            eval_interval: SimTime::from_secs_f64(5.0),
            ..Default::default()
        };
        let (m, traffic) = session(8, cfg).run();
        eprintln!(
            "dsgd: final_round={} best={:?} msgs={}",
            m.final_round,
            m.best_metric(true),
            traffic.messages()
        );
        assert!(m.final_round >= 30, "round {}", m.final_round);
        // D-SGD carries residual variance between local models (the
        // paper's central observation), so the bar is lower than the
        // MoDeST session test's 0.8.
        assert!(m.best_metric(true).unwrap() > 0.4, "best {:?}", m.best_metric(true));
        assert!(traffic.is_conserved());
    }

    #[test]
    fn traffic_is_evenly_balanced() {
        let cfg = DsgdConfig {
            max_time: SimTime::from_secs_f64(300.0),
            max_rounds: 20,
            ..Default::default()
        };
        let (_, traffic) = session(8, cfg).run();
        let (min, max) = traffic.min_max_usage(8);
        // Every node sends/receives exactly one model per round: near-equal.
        assert!(
            (max as f64) < 1.2 * (min as f64),
            "imbalanced D-SGD: {min} vs {max}"
        );
    }

    #[test]
    fn crashes_no_longer_deadlock_the_barrier() {
        use crate::sim::{ChurnEvent, ChurnKind};
        // Two of eight nodes crash early. Without churn tolerance the
        // pairwise barrier deadlocks within log2(n) rounds of the crash
        // (someone's in-neighbour never sends); with it, live nodes skip
        // the dead trainers and keep advancing.
        let churn = ChurnSchedule::new(vec![
            ChurnEvent { at: SimTime::from_secs_f64(10.0), node: 3, kind: ChurnKind::Crash },
            ChurnEvent { at: SimTime::from_secs_f64(15.0), node: 6, kind: ChurnKind::Leave },
        ]);
        let cfg = DsgdConfig {
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 40,
            eval_interval: SimTime::from_secs_f64(10.0),
            ..Default::default()
        };
        let (m, traffic) = session_with_churn(8, cfg, churn).run();
        assert!(m.final_round >= 25, "barrier stalled at round {}", m.final_round);
        let late = m.round_starts.iter().filter(|&(_, t)| t > 60.0).count();
        assert!(late > 5, "no progress after the crash window: {late}");
        assert!(traffic.is_conserved());
    }

    #[test]
    fn churn_round_trace_replays_identically() {
        use crate::sim::{ChurnEvent, ChurnKind};
        // Node 0 — the round-start recorder — leaves mid-run, so the
        // LivenessMirror hands the recorder role to node 1 while node 3's
        // crash exercises the barrier skip. The full (round, time) trace
        // and every fingerprint must replay bit-identically; the dedup
        // into sim::LivenessMirror moved this logic and must not perturb
        // the pre-refactor behaviour the assertions below pin.
        let mk = || {
            let churn = ChurnSchedule::new(vec![
                ChurnEvent { at: SimTime::from_secs_f64(10.0), node: 3, kind: ChurnKind::Crash },
                ChurnEvent { at: SimTime::from_secs_f64(25.0), node: 0, kind: ChurnKind::Leave },
            ]);
            let cfg = DsgdConfig {
                max_time: SimTime::from_secs_f64(600.0),
                max_rounds: 30,
                eval_interval: SimTime::from_secs_f64(10.0),
                ..Default::default()
            };
            session_with_churn(8, cfg, churn).run()
        };
        let (a, ta) = mk();
        let (b, tb) = mk();
        assert_eq!(a.events, b.events);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(ta.total(), tb.total());
        let trace = |m: &SessionMetrics| -> Vec<(Round, u64)> {
            m.round_starts.iter().map(|(r, t)| (r, t.to_bits())).collect()
        };
        assert_eq!(trace(&a), trace(&b));
        // The handoff recorded rounds past the leave instant, monotonically.
        let late = a.round_starts.iter().filter(|&(_, t)| t > 25.0).count();
        assert!(late > 0, "recorder handoff lost the trace after node 0 left");
        let rounds: Vec<Round> = a.round_starts.iter().map(|(r, _)| r).collect();
        let mut sorted = rounds.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(rounds, sorted, "trace not strictly monotone: {rounds:?}");
    }

    #[test]
    fn crashed_node_recovers_and_rejoins_the_barrier() {
        use crate::sim::{ChurnEvent, ChurnKind};
        // Node 3 crashes early and recovers mid-run (the availability
        // model's crash/recover shape). The barrier must not deadlock in
        // either direction: waiters skip the rounds node 3 missed, and
        // node 3 rejoins AT the training frontier with a barrier-free
        // first round instead of waiting for a round model that was
        // dropped while it was dead.
        let mk = || {
            let churn = ChurnSchedule::new(vec![
                ChurnEvent { at: SimTime::from_secs_f64(10.0), node: 3, kind: ChurnKind::Crash },
                ChurnEvent {
                    at: SimTime::from_secs_f64(40.0),
                    node: 3,
                    kind: ChurnKind::Recover,
                },
            ]);
            let cfg = DsgdConfig {
                max_time: SimTime::from_secs_f64(600.0),
                max_rounds: 40,
                eval_interval: SimTime::from_secs_f64(10.0),
                ..Default::default()
            };
            session_with_churn(8, cfg, churn).run()
        };
        let (m, traffic) = mk();
        // final_round is the min over LIVE nodes, so a recovered node
        // stuck at its crash-time round would pin it low.
        assert!(m.final_round >= 25, "stalled at round {}", m.final_round);
        let late = m.round_starts.iter().filter(|&(_, t)| t > 50.0).count();
        assert!(late > 3, "no progress after the recovery: {late}");
        assert!(traffic.is_conserved());
        // Deterministic replay, monotone trace — same bar as the other
        // churn sessions.
        let (b, tb) = mk();
        assert_eq!(m.events, b.events);
        assert_eq!(m.final_round, b.final_round);
        assert_eq!(traffic.total(), tb.total());
        let rounds: Vec<Round> = m.round_starts.iter().map(|(r, _)| r).collect();
        let mut sorted = rounds.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(rounds, sorted, "trace not strictly monotone: {rounds:?}");
    }

    #[test]
    fn builder_accepts_recover_but_rejects_fresh_joins() {
        use crate::sim::{ChurnEvent, ChurnKind};
        let mut spec = ScenarioSpec::new("mock", "dsgd");
        spec.population.nodes = 8;
        spec.run.max_time_s = 30.0;
        let recover = ChurnSchedule::new(vec![
            ChurnEvent { at: SimTime::from_secs_f64(2.0), node: 3, kind: ChurnKind::Crash },
            ChurnEvent { at: SimTime::from_secs_f64(5.0), node: 3, kind: ChurnKind::Recover },
        ]);
        assert!(DsgdBuilder.build(&spec, None, recover).is_ok());
        let join = ChurnSchedule::new(vec![ChurnEvent {
            at: SimTime::from_secs_f64(2.0),
            node: 9,
            kind: ChurnKind::Join,
        }]);
        let err = DsgdBuilder
            .build(&spec, None, join)
            .err()
            .expect("fresh join must be rejected");
        assert!(err.to_string().contains("fresh joiners"), "{err:#}");
    }

    #[test]
    fn lossy_links_time_out_instead_of_deadlocking() {
        use crate::net::LossModel;
        // 20% uniform loss on every link. Without the reliable outbox plus
        // the barrier backstop a dropped model deadlocks the pairwise
        // barrier within a few rounds; with them the session keeps
        // advancing, retransmitted bytes show up in the wire/goodput split,
        // and the attempt-level ledger still conserves.
        let mk = || {
            let cfg = DsgdConfig {
                max_time: SimTime::from_secs_f64(900.0),
                max_rounds: 20,
                eval_interval: SimTime::from_secs_f64(30.0),
                reliability: Some(ReliabilityConfig {
                    timeout: SimTime::from_secs_f64(3.0),
                    backoff: 2.0,
                    max_timeout: SimTime::from_secs_f64(10.0),
                    retries: 4,
                }),
                ..Default::default()
            };
            let n = 8;
            let mut rng = SimRng::new(cfg.seed);
            let task = MockTask::new(n, 16, 0.5, cfg.seed);
            let latency =
                LatencyMatrix::synthetic(&LatencyParams::default(), n, &mut rng.fork("lat"));
            let mut fabric = NetworkFabric::new(
                latency,
                &BandwidthConfig::uniform_mbps(50.0),
                n,
                &mut rng.fork("bw"),
            );
            fabric.set_loss(LossModel::Uniform { p: 0.2 }, rng.fork("loss"));
            let compute = ComputeModel::uniform(n, 0.05);
            DsgdSession::new(cfg, n, Box::new(task), compute, fabric, ChurnSchedule::empty())
                .run()
        };
        let (m, traffic) = mk();
        assert!(m.final_round >= 10, "lossy barrier stalled at round {}", m.final_round);
        assert!(traffic.dropped_bytes() > 0, "20% loss dropped nothing");
        assert!(traffic.retransmitted_bytes() > 0, "no retransmissions under loss");
        assert!(traffic.goodput() < traffic.total());
        assert!(traffic.is_conserved());
        // Same seed, same fault injection: bit-identical replay.
        let (b, tb) = mk();
        assert_eq!(m.events, b.events);
        assert_eq!(m.final_round, b.final_round);
        assert_eq!(traffic.total(), tb.total());
        assert_eq!(traffic.dropped_bytes(), tb.dropped_bytes());
    }

    #[test]
    fn every_node_participates_every_round() {
        let cfg = DsgdConfig {
            max_time: SimTime::from_secs_f64(200.0),
            max_rounds: 10,
            ..Default::default()
        };
        let (m, traffic) = session(6, cfg).run();
        // 6 nodes x >= 9 completed rounds x 1 model message each (the
        // session stops as soon as any node would enter round 11, so the
        // final round's tail messages may not all be sent).
        assert!(traffic.messages() >= 54, "{}", traffic.messages());
        assert!(m.final_round >= 10);
    }
}
