//! One-peer exponential graph topology (Ying et al. 2021, paper §4.3).
//!
//! Node `i` cycles round-robin through neighbours `i + 2^0, i + 2^1, ...,
//! i + 2^(τ-1) (mod n)` with `τ = ceil(log2 n)`: each round every node sends
//! to exactly one peer and receives from exactly one peer (the map
//! `i -> i + 2^j` is a bijection mod n), which is what makes the topology's
//! per-round communication cost exactly one model per node.

use crate::{NodeId, Round};

/// The one-peer exponential graph over `n` nodes.
#[derive(Debug, Clone, Copy)]
pub struct OnePeerExpGraph {
    n: u32,
    tau: u32,
}

impl OnePeerExpGraph {
    pub fn new(n: u32) -> OnePeerExpGraph {
        assert!(n >= 2, "need at least 2 nodes");
        let tau = (32 - (n - 1).leading_zeros()).max(1); // ceil(log2 n)
        OnePeerExpGraph { n, tau }
    }

    pub fn n(&self) -> u32 {
        self.n
    }

    /// Number of distinct neighbours each node cycles through (log2 n).
    pub fn degree(&self) -> u32 {
        self.tau
    }

    /// Whom node `i` sends its model to in `round` (1-based rounds).
    pub fn out_neighbor(&self, i: NodeId, round: Round) -> NodeId {
        let j = (round.wrapping_sub(1) % self.tau as u64) as u32;
        let hop = 1u64 << j;
        ((i as u64 + hop) % self.n as u64) as NodeId
    }

    /// Whom node `i` receives from in `round` (inverse of `out_neighbor`).
    pub fn in_neighbor(&self, i: NodeId, round: Round) -> NodeId {
        let j = (round.wrapping_sub(1) % self.tau as u64) as u32;
        let hop = 1u64 << j;
        (((i as u64 + self.n as u64) - (hop % self.n as u64)) % self.n as u64) as NodeId
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_is_log2() {
        assert_eq!(OnePeerExpGraph::new(2).degree(), 1);
        assert_eq!(OnePeerExpGraph::new(16).degree(), 4);
        assert_eq!(OnePeerExpGraph::new(17).degree(), 5);
        assert_eq!(OnePeerExpGraph::new(100).degree(), 7);
    }

    #[test]
    fn each_round_is_a_permutation() {
        let g = OnePeerExpGraph::new(10);
        for round in 1..=14u64 {
            let mut seen = vec![false; 10];
            for i in 0..10u32 {
                let o = g.out_neighbor(i, round) as usize;
                assert!(!seen[o], "round {round}: two senders hit {o}");
                seen[o] = true;
            }
        }
    }

    #[test]
    fn in_neighbor_inverts_out_neighbor() {
        let g = OnePeerExpGraph::new(23);
        for round in 1..=10u64 {
            for i in 0..23u32 {
                let o = g.out_neighbor(i, round);
                assert_eq!(g.in_neighbor(o, round), i);
            }
        }
    }

    #[test]
    fn cycles_through_all_hops() {
        let g = OnePeerExpGraph::new(16);
        let hops: Vec<NodeId> = (1..=4u64).map(|r| g.out_neighbor(0, r)).collect();
        assert_eq!(hops, vec![1, 2, 4, 8]);
        // round 5 wraps back to hop 1
        assert_eq!(g.out_neighbor(0, 5), 1);
    }

    #[test]
    fn never_self_loop_for_n_not_power_of_two_hop() {
        let g = OnePeerExpGraph::new(7);
        for round in 1..=20u64 {
            for i in 0..7u32 {
                assert_ne!(g.out_neighbor(i, round), i);
            }
        }
    }
}
