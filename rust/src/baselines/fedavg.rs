//! FedAvg baseline — emulated exactly as the paper does (§4.3):
//! "we use a = 1 and fix the aggregator node, i.e., nodes do not invoke the
//! sampling function. We fix the node with the lowest median latency to
//! other nodes to be the aggregator ... unlimited bandwidth capacity for
//! the aggregator ... sf = 1."

use anyhow::Result;

use crate::modest::ModestConfig;
use crate::net::LatencyMatrix;
use crate::runtime::XlaRuntime;
use crate::scenario::{ProtocolMeta, ScenarioSpec, Session, SessionBuilder};
use crate::sim::{ChurnSchedule, SimTime};

/// Derive the FedAvg emulation config from a MoDeST config: same `s`,
/// single fixed aggregator at the best-connected node, full success
/// fraction, and no failure-detection machinery. The server's unlimited
/// bandwidth is applied by `ModestSession::new` as a per-node capacity
/// override on the `NetworkFabric`. The per-round participant draw goes
/// through the harness `Population` (see `modest::session`), so a churned
/// population — e.g. one driven by a `population.availability` section —
/// samples only live clients without materializing a candidate list.
///
/// Under a lossy network (`network.loss`), FedAvg inherits the MoDeST
/// reliability stack via `..base.clone()`: model uploads/downloads ride
/// the reliable outbox, and the server — a fixed aggregator — arms the
/// aggregator deadline, so a participant whose upload expired is simply
/// replaced by the next round's fresh uniform draw instead of stalling
/// the round.
pub fn fedavg_config(base: &ModestConfig, latency: &LatencyMatrix, n: usize) -> ModestConfig {
    let server = latency.best_connected(n);
    ModestConfig {
        a: 1,
        sf: 1.0,
        fedavg_server: Some(server),
        // Sampling is disabled; the ping timeout is irrelevant but kept
        // sane for any residual timer.
        dt: SimTime::from_secs_f64(2.0),
        ..base.clone()
    }
}

/// Registry factory for the FedAvg emulation: the MoDeST stack under the
/// degenerate §4.3 config (shared assembly in [`crate::modest::builder`]).
pub struct FedavgBuilder;

impl SessionBuilder for FedavgBuilder {
    fn meta(&self) -> ProtocolMeta {
        ProtocolMeta {
            name: "fedavg",
            label: "FedAvg",
            aliases: &["fl"],
            summary: "federated-learning emulation (§4.3): one fixed \
                      best-connected aggregator with unlimited capacity, sf = 1",
            default_round_budget: 200,
            default_params: &[],
        }
    }

    fn build(
        &self,
        spec: &ScenarioSpec,
        runtime: Option<&XlaRuntime>,
        churn: ChurnSchedule,
    ) -> Result<Box<dyn Session>> {
        Ok(Box::new(crate::modest::assemble_modest(spec, runtime, churn, true)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimRng;

    #[test]
    fn picks_best_connected_server() {
        let mut rng = SimRng::new(4);
        let lat = LatencyMatrix::synthetic(&Default::default(), 30, &mut rng);
        let cfg = fedavg_config(&ModestConfig::default(), &lat, 30);
        assert_eq!(cfg.fedavg_server, Some(lat.best_connected(30)));
        assert_eq!(cfg.a, 1);
        assert_eq!(cfg.sf, 1.0);
    }
}
