//! Baseline algorithms the paper compares against (§2, §4.3).
//!
//! * **FedAvg** — emulated exactly as the paper does: the MoDeST stack with
//!   a fixed single aggregator (the best-connected node), `sf = 1`, no
//!   sampling pings, and unlimited server bandwidth. See [`fedavg`].
//! * **D-SGD** — decentralized SGD over a one-peer exponential graph
//!   (Ying et al.), the strongest DL topology the paper considers. See
//!   [`dsgd`].

pub mod dsgd;
pub mod fedavg;
pub mod topology;

pub use dsgd::{dsgd_config, DsgdBuilder, DsgdConfig, DsgdProtocol, DsgdSession};
pub use fedavg::{fedavg_config, FedavgBuilder};
pub use topology::OnePeerExpGraph;
