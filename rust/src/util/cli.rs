//! Minimal declarative CLI parsing for the launcher.
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional subcommands. Unknown flags are hard errors (catches typos in
//! experiment scripts early).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: subcommand path + flag map.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional words before any flag (e.g. `["exp", "fig3"]`).
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags that were consumed by a `get_*` call (for unknown-flag checks).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    // boolean flag
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.flags.is_empty() {
                out.positionals.push(arg);
            } else {
                bail!("positional argument {arg:?} after flags");
            }
        }
        Ok(out)
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(key, default as u64)? as usize)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, key: &str, default: &str) -> Vec<String> {
        self.get_str(key, default)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }

    pub fn get_usize_list(&self, key: &str, default: &str) -> Result<Vec<usize>> {
        self.get_list(key, default)
            .iter()
            .map(|s| s.parse::<usize>().map_err(|e| anyhow::anyhow!("--{key} item {s:?}: {e}")))
            .collect()
    }

    /// Error on any flag never consumed by a getter (typo protection).
    /// Every launcher path must call this after its getters ran, so a
    /// typoed `--bw-mpbs` fails loudly — with the closest known flag
    /// suggested when one is within two edits.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                let suggestion = seen
                    .iter()
                    .map(|s| (edit_distance(k, s), s))
                    .min()
                    .filter(|&(d, _)| d <= 2)
                    .map(|(_, s)| format!(" (did you mean --{s}?)"))
                    .unwrap_or_default();
                bail!("unknown flag --{k}{suggestion}");
            }
        }
        Ok(())
    }
}

/// Levenshtein distance, for near-miss flag suggestions.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, &ca) in a.iter().enumerate() {
        let mut row = vec![i + 1];
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            row.push(sub.min(prev[j + 1] + 1).min(row[j] + 1));
        }
        prev = row;
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommands_and_flags() {
        let a = args("exp fig3 --scale 0.5 --datasets cifar10,femnist --mock");
        assert_eq!(a.positionals, vec!["exp", "fig3"]);
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_list("datasets", ""), vec!["cifar10", "femnist"]);
        assert!(a.get_bool("mock"));
        assert!(!a.get_bool("absent"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let a = args("train --s=7 --sf=0.9");
        assert_eq!(a.get_usize("s", 0).unwrap(), 7);
        assert_eq!(a.get_f64("sf", 1.0).unwrap(), 0.9);
    }

    #[test]
    fn defaults_apply() {
        let a = args("train");
        assert_eq!(a.get_str("dataset", "cifar10"), "cifar10");
        assert_eq!(a.get_u64("seed", 42).unwrap(), 42);
        assert_eq!(a.get_opt("config"), None);
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = args("train --styp 3");
        let _ = a.get_usize("s", 0);
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn typoed_flag_suggests_nearest_known() {
        // The launcher's canonical failure mode: --bw-mpbs for --bw-mbps.
        let a = args("run --bw-mpbs 10");
        let _ = a.get_f64("bw-mbps", 50.0);
        let _ = a.get_u64("seed", 42);
        let err = a.reject_unknown().unwrap_err().to_string();
        assert!(err.contains("--bw-mpbs"), "{err}");
        assert!(err.contains("did you mean --bw-mbps"), "{err}");
    }

    #[test]
    fn distant_typos_get_no_suggestion() {
        let a = args("run --zzzzzz 1");
        let _ = a.get_u64("seed", 42);
        let err = a.reject_unknown().unwrap_err().to_string();
        assert!(err.contains("--zzzzzz"), "{err}");
        assert!(!err.contains("did you mean"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("bw-mpbs", "bw-mbps"), 2); // transposition = 2 edits
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("same", "same"), 0);
    }

    #[test]
    fn bad_number_is_error() {
        let a = args("x --n abc");
        assert!(a.get_u64("n", 0).is_err());
    }

    #[test]
    fn usize_list() {
        let a = args("x --s 1,2,4,7");
        assert_eq!(a.get_usize_list("s", "").unwrap(), vec![1, 2, 4, 7]);
    }
}
