//! Shared scaffolding for CSV trace files.
//!
//! Both trace formats the simulator plays back — per-node bandwidth
//! capacities (`scenario::network`) and per-node offline intervals
//! (`scenario::availability`) — share the same subtle envelope rules, and
//! the queued city-latency trace playback (ROADMAP) will be a third
//! consumer. [`parse_trace_rows`] implements them once:
//!
//! * blank lines and `#` comments are skipped;
//! * an unparseable row is tolerated as a **header** only before the
//!   first data row AND only when it leads with an ascii letter — a
//!   typoed first *data* row ("1O.0,100") must error, not be silently
//!   dropped and shift every subsequent node's assignment by one;
//! * parse failures surface with 1-based line numbers.

use anyhow::{bail, Result};

/// Drive `parse_row` over the data rows of `text`, calling `on_row` with
/// the 1-based line number for each parsed row (validation/collection
/// happens there; its errors propagate as-is). Returns whether any data
/// row parsed, so callers can reject empty traces with their own message.
pub fn parse_trace_rows<T>(
    text: &str,
    parse_row: impl Fn(&str) -> Result<T>,
    mut on_row: impl FnMut(usize, T) -> Result<()>,
) -> Result<bool> {
    let mut saw_data = false;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match parse_row(line) {
            Ok(row) => {
                saw_data = true;
                on_row(lineno + 1, row)?;
            }
            Err(_)
                if !saw_data
                    && line.chars().next().is_some_and(|c| c.is_ascii_alphabetic()) => {}
            Err(e) => bail!("trace line {}: {e}", lineno + 1),
        }
    }
    Ok(saw_data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    fn parse_num(line: &str) -> Result<f64> {
        line.parse().map_err(|e| anyhow!("bad number: {e}"))
    }

    fn collect(text: &str) -> Result<(bool, Vec<(usize, f64)>)> {
        let mut rows = Vec::new();
        let saw = parse_trace_rows(text, parse_num, |lineno, v| {
            rows.push((lineno, v));
            Ok(())
        })?;
        Ok((saw, rows))
    }

    #[test]
    fn skips_comments_blanks_and_one_leading_header() {
        let (saw, rows) = collect("# c\n\nvalue\n1.5\n2.5\n").unwrap();
        assert!(saw);
        assert_eq!(rows, vec![(4, 1.5), (5, 2.5)]);
    }

    #[test]
    fn header_tolerance_ends_at_the_first_data_row() {
        // A letter-leading junk row AFTER data must error with its line.
        let err = collect("1.0\nvalue\n2.0\n").unwrap_err();
        assert!(err.to_string().contains("trace line 2"), "{err:#}");
    }

    #[test]
    fn typoed_first_data_row_is_not_a_header() {
        // Leads with a digit, fails to parse: error, not silent drop.
        assert!(collect("1O.0\n2.0\n").is_err());
    }

    #[test]
    fn on_row_errors_propagate() {
        let out = parse_trace_rows("1.0\n-1.0\n", parse_num, |lineno, v| {
            anyhow::ensure!(v >= 0.0, "negative on line {lineno}");
            Ok(())
        });
        assert!(out.unwrap_err().to_string().contains("line 2"));
    }

    #[test]
    fn empty_traces_report_no_data() {
        assert!(!collect("# nothing\n").unwrap().0);
    }
}
