//! A strict, dependency-free JSON parser and writer.
//!
//! Covers the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes (incl. `\uXXXX` and surrogate pairs), numbers, booleans, null.
//! Numbers are held as f64 (adequate for the manifest/config payloads this
//! project reads). Object key order is preserved.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -------------------------------------------------------------- access

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Mandatory object field.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        anyhow::ensure!(n >= 0.0 && n.fract() == 0.0, "expected unsigned int, got {n}");
        Ok(n as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Object as a map for ordered iteration by key.
    pub fn to_map(&self) -> Result<BTreeMap<&str, &Json>> {
        Ok(self.as_obj()?.iter().map(|(k, v)| (k.as_str(), v)).collect())
    }

    // ------------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing data at byte {}", p.i);
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let c = self.peek().ok_or_else(|| anyhow!("unexpected end of input"))?;
        self.i += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        let got = self.bump()?;
        anyhow::ensure!(got == c, "expected {:?} at byte {}, got {:?}", c as char, self.i - 1, got as char);
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(pairs)),
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump()?;
            let d = (c as char).to_digit(16).ok_or_else(|| anyhow!("bad \\u escape"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.bump()?;
            match c {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            anyhow::ensure!(
                                (0xDC00..0xE000).contains(&lo),
                                "bad surrogate pair"
                            );
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?,
                        );
                    }
                    e => bail!("bad escape \\{:?}", e as char),
                },
                0x00..=0x1F => bail!("raw control char in string"),
                _ => {
                    // Re-decode UTF-8 multibyte sequences byte-faithfully.
                    let start = self.i - 1;
                    let len = utf8_len(c)?;
                    self.i = start + len;
                    anyhow::ensure!(self.i <= self.b.len(), "truncated utf-8");
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7F => Ok(1),
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("bad utf-8 lead byte"),
    }
}

// ------------------------------------------------------------------ writer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(pairs) => {
                write!(f, "{{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.field("c").unwrap().as_str().unwrap(), "x");
        let arr = v.field("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].field("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
        // Raw multibyte UTF-8 passes through.
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "\"unterminated", "1 2",
            "{\"a\" 1}", "[1 2]", "\"\\q\"", "nul",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn roundtrips_through_display() {
        let src = r#"{"name":"modest","n":355,"f":0.5,"ok":true,"xs":[1,2,3],"nested":{"deep":null}}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&String> = v.as_obj().unwrap().iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn typed_accessors_check_types() {
        let v = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(v.field("n").unwrap().as_usize().unwrap(), 3);
        assert!(v.field("s").unwrap().as_u64().is_err());
        assert!(v.field("n").unwrap().as_str().is_err());
        assert!(v.field("missing").is_err());
        assert!(Json::parse("3.5").unwrap().as_u64().is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
            "seed": 42,
            "variants": {
                "celeba": {"param_count": 30242, "lr": 0.001,
                           "files": {"train": "a", "eval": "b"}}
            }
        }"#;
        let v = Json::parse(src).unwrap();
        let celeba = v.field("variants").unwrap().field("celeba").unwrap();
        assert_eq!(celeba.field("param_count").unwrap().as_usize().unwrap(), 30242);
        assert!((celeba.field("lr").unwrap().as_f64().unwrap() - 0.001).abs() < 1e-12);
    }
}
