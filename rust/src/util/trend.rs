//! Cross-PR bench-trend comparison over `BENCH_hotpaths.json` snapshots.
//!
//! CI stashes one snapshot per commit as an artifact; the `bench-diff`
//! binary (`src/bin/bench_diff.rs`) loads the base commit's snapshot and
//! the fresh one, compares per-bench medians, and fails the job when a
//! **guarded** hot path — DES queue push/pop, fan-out, peer sampling —
//! regresses by more than the threshold (closing the ROADMAP "track
//! BENCH_hotpaths.json across PRs" item). Non-guarded rows are reported
//! but never fail the build: they are informational trajectory, not
//! acceptance bars.

use anyhow::{Context, Result};

use super::json::Json;

/// Bench-name prefixes whose regression fails the build. Everything else
/// (aggregation kernels, view merges, ...) is tracked but advisory.
pub const GUARDED_PREFIXES: &[&str] = &[
    "des/queue/",
    "fanout/",
    "sample/",
    "mem/",
    "snapshot/",
    "loss/",
    "reliability/",
    "obs/",
    "par/",
];

/// Guarded rows faster than this in BOTH snapshots are exempt from the
/// ratio gate: a 2x swing on a tens-of-nanoseconds row is scheduler noise
/// on shared CI runners, not a regression.
pub const MIN_GUARDED_NS: u64 = 500;

/// One bench row of a snapshot (the median is what trends compare —
/// p50 is far more stable across runners than the mean).
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRow {
    pub name: String,
    pub p50_ns: u64,
}

/// Parse the `Bencher::to_json` format (`{"group": ..., "results": [...]}`).
pub fn parse_snapshot(text: &str) -> Result<Vec<BenchRow>> {
    let v = Json::parse(text).context("bench snapshot is not valid JSON")?;
    v.field("results")?
        .as_arr()?
        .iter()
        .map(|r| {
            Ok(BenchRow {
                name: r.field("name")?.as_str()?.to_string(),
                p50_ns: r.field("p50_ns")?.as_u64()?,
            })
        })
        .collect()
}

/// One compared row: `ratio` > 1 means the new snapshot is slower.
#[derive(Debug, Clone)]
pub struct TrendDiff {
    pub name: String,
    pub base_ns: u64,
    pub new_ns: u64,
    pub ratio: f64,
    /// Name matched a guarded prefix (eligible to fail the build).
    pub guarded: bool,
}

impl TrendDiff {
    /// Whether this row trips the gate at `threshold` (e.g. 2.0 = fail on
    /// a >2x median regression).
    pub fn fails(&self, threshold: f64) -> bool {
        self.guarded
            && self.ratio > threshold
            && (self.base_ns >= MIN_GUARDED_NS || self.new_ns >= MIN_GUARDED_NS)
    }
}

/// Compare two snapshots by bench name. Rows present in only one snapshot
/// are skipped (benches come and go across PRs); an empty intersection is
/// not an error — the caller reports it and passes (first run on a branch,
/// or the committed empty-baseline fallback).
pub fn compare_trend(base: &[BenchRow], new: &[BenchRow]) -> Vec<TrendDiff> {
    new.iter()
        .filter_map(|n| {
            let b = base.iter().find(|b| b.name == n.name)?;
            Some(TrendDiff {
                name: n.name.clone(),
                base_ns: b.p50_ns,
                new_ns: n.p50_ns,
                ratio: if b.p50_ns == 0 {
                    if n.p50_ns == 0 { 1.0 } else { f64::INFINITY }
                } else {
                    n.p50_ns as f64 / b.p50_ns as f64
                },
                guarded: GUARDED_PREFIXES.iter().any(|p| n.name.starts_with(p)),
            })
        })
        .collect()
}

/// The rows that fail the gate at `threshold`, worst first.
pub fn regressions(diffs: &[TrendDiff], threshold: f64) -> Vec<&TrendDiff> {
    let mut out: Vec<&TrendDiff> = diffs.iter().filter(|d| d.fails(threshold)).collect();
    out.sort_by(|a, b| b.ratio.total_cmp(&a.ratio));
    out
}

/// Guarded prefixes the gate is *blind* to in this comparison: the new
/// snapshot has rows under the prefix but the base has none, so no
/// regression there can ever trip. Historically this failed silently — a
/// stale or empty baseline made the whole gate pass vacuously while
/// looking green. `bench-diff` turns each returned prefix into a loud CI
/// `::warning::` annotation instead.
pub fn missing_guarded_coverage(base: &[BenchRow], new: &[BenchRow]) -> Vec<&'static str> {
    GUARDED_PREFIXES
        .iter()
        .filter(|p| {
            new.iter().any(|r| r.name.starts_with(**p))
                && !base.iter().any(|r| r.name.starts_with(**p))
        })
        .copied()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snapshot(rows: &[(&str, u64)]) -> Vec<BenchRow> {
        rows.iter()
            .map(|&(name, p50_ns)| BenchRow { name: name.to_string(), p50_ns })
            .collect()
    }

    #[test]
    fn parses_bencher_json_output() {
        // Exactly the format Bencher::to_json writes.
        std::env::set_var("BENCH_FAST", "1");
        let mut b = crate::util::bench::Bencher::new("trendtest");
        b.bench("des/queue/unit", || {
            crate::util::bench::black_box((0..64).sum::<u64>());
        });
        let rows = parse_snapshot(&b.to_json()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].name, "des/queue/unit");
    }

    #[test]
    fn rejects_malformed_snapshots() {
        assert!(parse_snapshot("not json").is_err());
        assert!(parse_snapshot(r#"{"group": "x"}"#).is_err());
        assert!(parse_snapshot(r#"{"results": [{"name": "a"}]}"#).is_err());
    }

    #[test]
    fn injected_2x_queue_regression_fails_the_gate() {
        // The CI self-check scenario: same snapshot with the queue rows
        // doctored 2.5x slower must trip the >2x gate.
        let base = snapshot(&[
            ("des/queue/hold-100000/calendar", 80_000_000),
            ("fanout/arc-msgs/8-of-1.75M", 900),
            ("aggregate/native/10x86k(cifar10)", 500_000),
        ]);
        let new = snapshot(&[
            ("des/queue/hold-100000/calendar", 200_000_000),
            ("fanout/arc-msgs/8-of-1.75M", 950),
            ("aggregate/native/10x86k(cifar10)", 500_000),
        ]);
        let diffs = compare_trend(&base, &new);
        let bad = regressions(&diffs, 2.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].name, "des/queue/hold-100000/calendar");
        assert!(bad[0].ratio > 2.0);
    }

    #[test]
    fn unguarded_rows_never_fail() {
        let base = snapshot(&[("view/merge/500-nodes", 1_000_000)]);
        let new = snapshot(&[("view/merge/500-nodes", 10_000_000)]);
        let diffs = compare_trend(&base, &new);
        assert_eq!(diffs.len(), 1);
        assert!((diffs[0].ratio - 10.0).abs() < 1e-9);
        assert!(regressions(&diffs, 2.0).is_empty());
    }

    #[test]
    fn churned_sample_rows_are_guarded() {
        // The churned-path rows added with the Population/Fenwick work sit
        // under the `sample/` prefix and must trip the gate like the
        // all-alive rows — a regression back to O(alive) materialization
        // at n=100k is exactly what this gate exists to catch.
        let base = snapshot(&[
            ("sample/churned-v2/n=100000,k=10", 3_000),
            ("sample/churned-v1/n=100000,k=10", 900_000),
        ]);
        let new = snapshot(&[
            ("sample/churned-v2/n=100000,k=10", 12_000),
            ("sample/churned-v1/n=100000,k=10", 950_000),
        ]);
        let bad = regressions(&compare_trend(&base, &new), 2.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].name, "sample/churned-v2/n=100000,k=10");
    }

    #[test]
    fn sample_and_fanout_rows_are_guarded() {
        let base = snapshot(&[
            ("sample/v2-partial/n=100000,k=10", 2_000),
            ("fanout/arc-msgs/10k-of-1.75M", 400_000),
        ]);
        let new = snapshot(&[
            ("sample/v2-partial/n=100000,k=10", 9_000),
            ("fanout/arc-msgs/10k-of-1.75M", 700_000),
        ]);
        let bad = regressions(&compare_trend(&base, &new), 2.0);
        assert_eq!(bad.len(), 1, "1.75x fan-out drift must not fail");
        assert_eq!(bad[0].name, "sample/v2-partial/n=100000,k=10");
    }

    #[test]
    fn mem_budget_rows_are_guarded() {
        // The byte-budget rows from the memory-diet work are value rows
        // (bytes parked in the ns fields) under the `mem/` prefix; a node
        // struct quietly regrowing past 2x per node must fail the build
        // exactly like a hot-path slowdown.
        let base = snapshot(&[
            ("mem/bytes-per-node/n=100000", 320),
            ("mem/bytes-per-node/n=10000", 410),
        ]);
        let new = snapshot(&[
            ("mem/bytes-per-node/n=100000", 980),
            ("mem/bytes-per-node/n=10000", 430),
        ]);
        let bad = regressions(&compare_trend(&base, &new), 2.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].name, "mem/bytes-per-node/n=100000");
        assert!(bad[0].guarded);
    }

    #[test]
    fn snapshot_rows_are_guarded() {
        // Checkpoint write/read at n=100k and the on-disk byte size are
        // guarded like the other hot paths: a 2x blowup in snapshot cost
        // (an accidental deep copy per node, interning silently disabled)
        // must fail the build, not scroll past as trivia.
        let base = snapshot(&[
            ("snapshot/write/n=100k", 40_000_000),
            ("snapshot/bytes/n=100k", 9_000_000),
        ]);
        let new = snapshot(&[
            ("snapshot/write/n=100k", 110_000_000),
            ("snapshot/bytes/n=100k", 9_100_000),
        ]);
        let bad = regressions(&compare_trend(&base, &new), 2.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].name, "snapshot/write/n=100k");
        assert!(bad[0].guarded);
    }

    #[test]
    fn loss_and_reliability_rows_are_guarded() {
        // The fault-injection decision sits on the fabric's per-transfer
        // hot path and the retransmit sweep bounds the outbox overhead; a
        // 2x regression on either must fail the build like the DES queue.
        let base = snapshot(&[
            ("loss/decide/n=100000", 400_000),
            ("reliability/retransmit-sweep/n=64,p=0.3", 8_000_000),
        ]);
        let new = snapshot(&[
            ("loss/decide/n=100000", 1_000_000),
            ("reliability/retransmit-sweep/n=64,p=0.3", 8_500_000),
        ]);
        let bad = regressions(&compare_trend(&base, &new), 2.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].name, "loss/decide/n=100000");
        assert!(bad[0].guarded);
    }

    #[test]
    fn obs_rows_are_guarded() {
        // The streaming-observability rows sit on per-transfer and
        // per-round hot paths (histogram record, HLL insert) plus the
        // progress-tick render; a 2x regression there would make the
        // "bounded work per tick" promise a lie, so they gate like the
        // DES queue.
        let base = snapshot(&[
            ("obs/hist-record/x1024", 4_000),
            ("obs/hll-insert/n=100000", 300_000),
            ("obs/progress-tick/n=100000", 2_000),
        ]);
        let new = snapshot(&[
            ("obs/hist-record/x1024", 12_000),
            ("obs/hll-insert/n=100000", 310_000),
            ("obs/progress-tick/n=100000", 2_100),
        ]);
        let bad = regressions(&compare_trend(&base, &new), 2.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].name, "obs/hist-record/x1024");
        assert!(bad[0].guarded);
    }

    #[test]
    fn par_rows_are_guarded() {
        // The sharded-scheduler rows are the parallel-speedup acceptance
        // bar: a window-merge slowdown or the t=4 hold model drifting back
        // toward t=1 silently erases the headline win, so they gate like
        // the DES queue rows they shard.
        let base = snapshot(&[
            ("par/window-merge/n=100k", 20_000_000),
            ("par/harness-step/n=100k,t=1", 300_000_000),
            ("par/harness-step/n=100k,t=4", 120_000_000),
        ]);
        let new = snapshot(&[
            ("par/window-merge/n=100k", 55_000_000),
            ("par/harness-step/n=100k,t=1", 310_000_000),
            ("par/harness-step/n=100k,t=4", 130_000_000),
        ]);
        let bad = regressions(&compare_trend(&base, &new), 2.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].name, "par/window-merge/n=100k");
        assert!(bad[0].guarded);
    }

    #[test]
    fn missing_guarded_coverage_flags_blind_prefixes() {
        // Base lacks any obs/ row while the new snapshot has one: the
        // gate cannot catch obs regressions, and the caller must warn.
        let base = snapshot(&[("des/queue/hold-100000/calendar", 80_000_000)]);
        let new = snapshot(&[
            ("des/queue/hold-100000/calendar", 81_000_000),
            ("obs/hll-insert/n=100000", 300_000),
        ]);
        assert_eq!(missing_guarded_coverage(&base, &new), vec!["obs/"]);
        // An empty base is blind to every guarded prefix present in new.
        assert_eq!(missing_guarded_coverage(&[], &new), vec!["des/queue/", "obs/"]);
        // Full coverage (or a prefix absent from new too) warns nothing.
        assert!(missing_guarded_coverage(&new, &new).is_empty());
        assert!(missing_guarded_coverage(&base, &base).is_empty());
    }

    #[test]
    fn nanosecond_noise_is_exempt() {
        // 3x on a 90ns row: scheduler noise, below MIN_GUARDED_NS.
        let base = snapshot(&[("fanout/arc-msgs/tiny", 90)]);
        let new = snapshot(&[("fanout/arc-msgs/tiny", 280)]);
        assert!(regressions(&compare_trend(&base, &new), 2.0).is_empty());
    }

    #[test]
    fn disjoint_snapshots_compare_empty() {
        let base = snapshot(&[("old/bench", 1_000)]);
        let new = snapshot(&[("new/bench", 1_000)]);
        assert!(compare_trend(&base, &new).is_empty());
        assert!(compare_trend(&[], &new).is_empty());
    }

    #[test]
    fn speedups_and_parity_pass() {
        let base = snapshot(&[("des/queue/hold-1000000/calendar", 100_000_000)]);
        let new = snapshot(&[("des/queue/hold-1000000/calendar", 60_000_000)]);
        let diffs = compare_trend(&base, &new);
        assert!(regressions(&diffs, 2.0).is_empty());
        assert!(diffs[0].ratio < 1.0);
    }
}
