//! In-tree substrates for an offline build.
//!
//! The build environment ships only the `xla` crate closure and `anyhow`,
//! so the utilities a production coordinator would normally pull from
//! crates.io are implemented here, each with its own test suite:
//!
//! * [`json`]  — a strict recursive-descent JSON parser + writer (used for
//!   the artifact manifest and session config files).
//! * [`cli`]   — declarative flag/subcommand parsing for the launcher.
//! * [`bench`] — a criterion-style micro/macro benchmark harness with
//!   warmup, adaptive iteration counts, and mean/p50/p95 reporting.
//! * [`trend`] — cross-PR comparison of `BENCH_hotpaths.json` snapshots
//!   (the CI `bench-diff` regression gate).
//! * [`rows`]  — shared CSV trace-file scaffolding (comment/header
//!   tolerance, line-numbered errors) for bandwidth and availability
//!   traces.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rows;
pub mod trend;

pub use json::Json;
pub use rows::parse_trace_rows;
