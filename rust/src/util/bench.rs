//! A small criterion-style benchmark harness (the image has no criterion).
//!
//! Usage inside a `harness = false` bench target:
//! ```no_run
//! use modest_dl::util::bench::Bencher;
//! let mut b = Bencher::new("hotpaths");
//! b.bench("aggregate/8x1M", || { /* work */ });
//! b.finish();
//! ```
//!
//! Each benchmark warms up, then runs timed batches until a time budget is
//! hit, reporting mean / p50 / p95 per iteration and iterations/s in a
//! table. `BENCH_FAST=1` shrinks budgets for CI smoke runs.

use std::time::{Duration, Instant};

/// One benchmark's aggregated result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() > 0.0 {
            1.0 / self.mean.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Collects and prints benchmark results for one bench binary.
pub struct Bencher {
    group: String,
    warmup: Duration,
    budget: Duration,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(group: &str) -> Bencher {
        let fast = std::env::var("BENCH_FAST").is_ok();
        Bencher {
            group: group.to_string(),
            warmup: if fast { Duration::from_millis(20) } else { Duration::from_millis(300) },
            budget: if fast { Duration::from_millis(100) } else { Duration::from_secs(2) },
            results: Vec::new(),
        }
    }

    /// Override budgets (e.g. long end-to-end benches).
    pub fn with_budget(mut self, warmup: Duration, budget: Duration) -> Bencher {
        self.warmup = warmup;
        self.budget = budget;
        self
    }

    /// Time `f`; `f` should do one unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Timed samples.
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            if samples.len() >= 100_000 {
                break;
            }
        }
        samples.sort_unstable();
        let n = samples.len().max(1) as u64;
        let total: Duration = samples.iter().sum();
        let result = BenchResult {
            name: name.to_string(),
            iterations: n,
            mean: total / n as u32,
            p50: samples.get(samples.len() / 2).copied().unwrap_or_default(),
            p95: samples
                .get(samples.len() * 95 / 100)
                .copied()
                .unwrap_or_default(),
        };
        println!(
            "{:<44} {:>10} iters  mean {:>10}  p50 {:>10}  p95 {:>10}  ({:.1}/s)",
            format!("{}/{}", self.group, result.name),
            result.iterations,
            fmt_dur(result.mean),
            fmt_dur(result.p50),
            fmt_dur(result.p95),
            result.per_sec()
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Run a one-shot measurement (for long end-to-end scenarios): time a
    /// single invocation, printed in the same table format.
    pub fn bench_once<F: FnOnce()>(&mut self, name: &str, f: F) -> &BenchResult {
        let t0 = Instant::now();
        f();
        let d = t0.elapsed();
        let result = BenchResult {
            name: name.to_string(),
            iterations: 1,
            mean: d,
            p50: d,
            p95: d,
        };
        println!(
            "{:<44} {:>10} iters  mean {:>10}  p50 {:>10}  p95 {:>10}",
            format!("{}/{}", self.group, result.name),
            1,
            fmt_dur(d),
            fmt_dur(d),
            fmt_dur(d)
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record a raw measured value (bytes, counts, ...) as a one-iteration
    /// row. The value lands in the `*_ns` JSON fields so the trend gate
    /// compares it exactly like a timing row — `mem/bytes-per-node/...`
    /// rows ride the same snapshot diff as the hot-path timings.
    pub fn record_value(&mut self, name: &str, value: u64) -> &BenchResult {
        let d = Duration::from_nanos(value);
        let result = BenchResult {
            name: name.to_string(),
            iterations: 1,
            mean: d,
            p50: d,
            p95: d,
        };
        println!(
            "{:<44} {:>10} value",
            format!("{}/{}", self.group, result.name),
            value
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Serialize every recorded result as machine-readable JSON so future
    /// PRs can track the trajectory (`BENCH_hotpaths.json` et al.).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"group\": \"{}\",\n", self.group));
        out.push_str("  \"results\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"iterations\": {}, \"mean_ns\": {}, \
                 \"p50_ns\": {}, \"p95_ns\": {}, \"per_sec\": {:.3}}}{}\n",
                r.name,
                r.iterations,
                r.mean.as_nanos(),
                r.p50.as_nanos(),
                r.p95.as_nanos(),
                r.per_sec(),
                if i + 1 == self.results.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON summary to `path` (best-effort: a read-only CI
    /// checkout must not fail the bench run itself).
    pub fn write_json(&self, path: &str) {
        match std::fs::write(path, self.to_json()) {
            Ok(()) => println!("{}: results written to {path}", self.group),
            Err(e) => eprintln!("{}: could not write {path}: {e}", self.group),
        }
    }

    /// Print the summary footer.
    pub fn finish(self) {
        println!(
            "{}: {} benchmarks complete",
            self.group,
            self.results.len()
        );
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bencher::new("test");
        let r = b
            .bench("noop-ish", || {
                black_box((0..100).sum::<u64>());
            })
            .clone();
        assert!(r.iterations > 10);
        assert!(r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn bench_once_records_single_run() {
        let mut b = Bencher::new("test");
        let r = b.bench_once("single", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(r.iterations, 1);
        assert!(r.mean >= Duration::from_millis(2));
    }

    #[test]
    fn json_lists_every_result() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bencher::new("jtest");
        b.bench("one", || {
            black_box(1 + 1);
        });
        b.bench("two", || {
            black_box(2 + 2);
        });
        let j = b.to_json();
        assert!(j.contains("\"group\": \"jtest\""));
        assert!(j.contains("\"name\": \"one\""));
        assert!(j.contains("\"name\": \"two\""));
        assert!(j.contains("\"mean_ns\""));
        // Exactly one trailing entry without a comma.
        assert_eq!(j.matches("},").count(), 1);
    }

    #[test]
    fn record_value_round_trips_through_json() {
        let mut b = Bencher::new("vtest");
        let r = b.record_value("mem/bytes-per-node/n=100000", 184).clone();
        assert_eq!(r.iterations, 1);
        assert_eq!(r.p50.as_nanos(), 184);
        let j = b.to_json();
        assert!(j.contains("\"name\": \"mem/bytes-per-node/n=100000\""));
        assert!(j.contains("\"p50_ns\": 184"));
    }

    #[test]
    fn fmt_dur_ranges() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(15)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(20)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(3)).ends_with('s'));
    }
}
