//! The learning abstraction the protocols run against.
//!
//! Sessions (MoDeST / FedAvg / D-SGD) never touch PJRT or datasets
//! directly; they see a [`Task`]: init a model, run one local epoch on a
//! node's shard, aggregate models, evaluate on the global test set. Two
//! implementations exist:
//!
//! * [`xla_task::XlaTask`] — the production path over the AOT'd artifacts.
//! * [`mock::MockTask`] — a closed-form quadratic task for protocol tests,
//!   property tests and simulator-heavy experiments (Fig. 5 needs no real
//!   learning), so `cargo test` stays fast and artifact-free.

pub mod agg;
pub mod compute;
pub mod mock;
pub mod task;
#[cfg(feature = "xla")]
pub mod xla_task;

pub use agg::{aggregate_native, aggregate_weighted};
pub use compute::ComputeModel;
pub use mock::MockTask;
pub use task::{EvalResult, Model, Task};
#[cfg(feature = "xla")]
pub use xla_task::{AggBackend, TaskData, XlaTask};
