//! The production `Task`: AOT'd XLA executables + synthetic shards.
//!
//! One `XlaTask` owns the compiled variant runtime, the generated dataset,
//! and scratch state. Local updates run the paper's E=1 pass over the
//! node's shard in B-sized batches through the `train` executable (which
//! embeds the Pallas dense fwd/bwd and fused SGD kernels); evaluation
//! streams the global test set through the `eval` executable.

use anyhow::Result;

use crate::data::{ClassifData, RatingsData, TokensData};
use crate::runtime::{Batch, VariantRuntime, XlaRuntime};
use crate::sim::SimRng;
use crate::NodeId;

use super::agg::aggregate_native;
use super::task::{EvalResult, Model, Task};

/// Which backend computes `AVG(Θ)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AggBackend {
    /// Native rust mean (default: fastest on CPU, see §Perf).
    #[default]
    Native,
    /// The AOT'd Pallas masked-mean kernel via PJRT.
    Xla,
}

/// Dataset payload per task kind.
pub enum TaskData {
    Classif(ClassifData),
    Ratings(RatingsData),
    Tokens(TokensData),
}

pub struct XlaTask {
    rt: VariantRuntime,
    data: TaskData,
    pub agg_backend: AggBackend,
    /// Learning rate / momentum (from the manifest = paper Table 3).
    lr: f32,
    momentum: f32,
}

impl XlaTask {
    /// Compile the variant and attach a generated dataset.
    pub fn new(runtime: &XlaRuntime, variant: &str, data: TaskData) -> Result<XlaTask> {
        let rt = runtime.variant(variant)?;
        // Sanity: dataset kind must match the variant kind.
        match (&data, rt.manifest.kind.as_str()) {
            (TaskData::Classif(_), "classifier")
            | (TaskData::Ratings(_), "matfact")
            | (TaskData::Tokens(_), "lm") => {}
            (_, kind) => anyhow::bail!("dataset does not match variant kind {kind}"),
        }
        let lr = rt.manifest.lr;
        let momentum = rt.manifest.momentum;
        Ok(XlaTask { rt, data, agg_backend: AggBackend::Native, lr, momentum })
    }

    pub fn manifest(&self) -> &crate::runtime::VariantManifest {
        &self.rt.manifest
    }

    fn train_batch_size(&self) -> usize {
        self.rt.manifest.train_batch
    }

    /// Node shard size in samples/sequences.
    fn shard_len(&self, node: NodeId) -> usize {
        match &self.data {
            TaskData::Classif(d) => d.shards[node as usize].len(),
            TaskData::Ratings(d) => d.shards[node as usize].len(),
            TaskData::Tokens(d) => d.shard(node as usize).len(),
        }
    }

    /// Assemble one train batch from shard positions (wrapping pad).
    fn make_batch(&self, node: NodeId, order: &[u32], start: usize) -> Batch {
        let b = self.train_batch_size();
        let take = |k: usize| order[(start + k) % order.len()];
        match &self.data {
            TaskData::Classif(d) => {
                let dim = d.dim;
                let mut x = Vec::with_capacity(b * dim);
                let mut y = Vec::with_capacity(b);
                for k in 0..b {
                    let idx = d.shards[node as usize][take(k) as usize];
                    x.extend_from_slice(d.train_row(idx));
                    y.push(d.train_y[idx as usize]);
                }
                Batch::F32I32 { x, y }
            }
            TaskData::Ratings(d) => {
                let mut x = Vec::with_capacity(b * 2);
                let mut y = Vec::with_capacity(b);
                for k in 0..b {
                    let idx = d.shards[node as usize][take(k) as usize];
                    let (u, i, r) = d.train[idx as usize];
                    x.push(u as i32);
                    x.push(i as i32);
                    y.push(r);
                }
                Batch::I32F32 { x, y }
            }
            TaskData::Tokens(d) => {
                let shard = d.shard(node as usize);
                let t = d.seq_len;
                let mut x = Vec::with_capacity(b * t);
                let mut y = Vec::with_capacity(b * t);
                for k in 0..b {
                    let seq_idx = shard.start + take(k) as usize;
                    let seq = d.train_seq(seq_idx);
                    x.extend_from_slice(&seq[..t]);
                    y.extend_from_slice(&seq[1..t + 1]);
                }
                Batch::I32I32 { x, y }
            }
        }
    }

    /// Test batches (full multiples of eval_batch only, deterministic).
    fn eval_batches(&self) -> Vec<(Batch, usize)> {
        let b = self.rt.manifest.eval_batch;
        let mut out = Vec::new();
        match &self.data {
            TaskData::Classif(d) => {
                let n = (d.n_test() / b) * b;
                for s in (0..n).step_by(b) {
                    let x = d.test_x[s * d.dim..(s + b) * d.dim].to_vec();
                    let y = d.test_y[s..s + b].to_vec();
                    out.push((Batch::F32I32 { x, y }, b));
                }
            }
            TaskData::Ratings(d) => {
                let n = (d.test.len() / b) * b;
                for s in (0..n).step_by(b) {
                    let mut x = Vec::with_capacity(b * 2);
                    let mut y = Vec::with_capacity(b);
                    for &(u, i, r) in &d.test[s..s + b] {
                        x.push(u as i32);
                        x.push(i as i32);
                        y.push(r);
                    }
                    out.push((Batch::I32F32 { x, y }, b));
                }
            }
            TaskData::Tokens(d) => {
                let n = (d.n_test_seqs() / b) * b;
                let t = d.seq_len;
                for s in (0..n).step_by(b) {
                    let mut x = Vec::with_capacity(b * t);
                    let mut y = Vec::with_capacity(b * t);
                    for q in s..s + b {
                        let seq = d.test_seq(q);
                        x.extend_from_slice(&seq[..t]);
                        y.extend_from_slice(&seq[1..t + 1]);
                    }
                    out.push((Batch::I32I32 { x, y }, b * t));
                }
            }
        }
        out
    }
}

impl Task for XlaTask {
    fn param_count(&self) -> usize {
        self.rt.param_count()
    }

    fn model_bytes(&self) -> u64 {
        self.rt.manifest.model_bytes
    }

    fn init_model(&self) -> Model {
        self.rt.init_params()
    }

    fn local_update(
        &mut self,
        model: &Model,
        node: NodeId,
        seed: u64,
    ) -> Result<(Model, f32, u32)> {
        let shard_len = self.shard_len(node);
        anyhow::ensure!(shard_len > 0, "node {node} has an empty shard");
        let mut order: Vec<u32> = (0..shard_len as u32).collect();
        let mut rng = SimRng::new(seed);
        rng.shuffle(&mut order);

        let batches = self.batches_per_epoch(node);
        let b = self.train_batch_size();
        let mut params = model.clone();
        let mut velocity = vec![0f32; params.len()]; // fresh optimizer state per round
        let mut loss_sum = 0f64;
        for i in 0..batches {
            let batch = self.make_batch(node, &order, i as usize * b);
            let out = self.rt.train_step(&params, &velocity, &batch, self.lr, self.momentum)?;
            params = out.params;
            velocity = out.velocity;
            loss_sum += out.loss as f64;
        }
        Ok((params, (loss_sum / batches as f64) as f32, batches))
    }

    fn batches_per_epoch(&self, node: NodeId) -> u32 {
        let shard = self.shard_len(node).max(1);
        shard.div_ceil(self.train_batch_size()) as u32
    }

    fn evaluate(&mut self, model: &Model) -> Result<EvalResult> {
        let mut metric_sum = 0f64;
        let mut loss_sum = 0f64;
        let mut n = 0usize;
        for (batch, count) in self.eval_batches() {
            let out = self.rt.eval_batch(model, &batch)?;
            metric_sum += out.metric_sum as f64;
            loss_sum += out.loss_sum as f64;
            n += count;
        }
        anyhow::ensure!(n > 0, "empty test set");
        Ok(EvalResult { metric: metric_sum / n as f64, loss: loss_sum / n as f64 })
    }

    fn aggregate(&mut self, models: &[&Model]) -> Result<Model> {
        match self.agg_backend {
            AggBackend::Native => Ok(aggregate_native(models)),
            AggBackend::Xla => {
                let slices: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
                self.rt.aggregate(&slices)
            }
        }
    }

    fn metric_is_accuracy(&self) -> bool {
        self.rt.manifest.kind != "matfact"
    }
}
