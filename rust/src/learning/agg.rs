//! Native model averaging — the L3 aggregation hot path.
//!
//! An aggregator averages up to `s` models of up to ~1.75M f32 each, every
//! round. This implementation accumulates in f32 with the models as the
//! outer loop and a plain slice add as the inner loop, which LLVM
//! auto-vectorizes; `benches/hotpaths.rs` compares it against the
//! XLA/Pallas path and a naive index-per-element loop (see EXPERIMENTS.md
//! §Perf for numbers).

use super::task::Model;

/// Mean of `models` (all same length, at least one).
pub fn aggregate_native(models: &[&Model]) -> Model {
    assert!(!models.is_empty(), "aggregate of zero models");
    let n = models[0].len();
    let mut acc = models[0].clone();
    for m in &models[1..] {
        assert_eq!(m.len(), n, "model length mismatch");
        // Slice-of-equal-length add: bounds checks hoisted, vectorized.
        for (a, &b) in acc.iter_mut().zip(m.iter()) {
            *a += b;
        }
    }
    let inv = 1.0 / models.len() as f32;
    for a in &mut acc {
        *a *= inv;
    }
    acc
}

/// Weighted mean (FedAvg-style weighting by sample counts, available for
/// extensions; the paper's MoDeST uses the unweighted mean).
pub fn aggregate_weighted(models: &[&Model], weights: &[f32]) -> Model {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty());
    let total: f32 = weights.iter().sum();
    assert!(total > 0.0, "zero total weight");
    let n = models[0].len();
    let mut acc = vec![0f32; n];
    for (m, &w) in models.iter().zip(weights) {
        let scale = w / total;
        for (a, &b) in acc.iter_mut().zip(m.iter()) {
            *a += scale * b;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_two() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 2.0, 1.0];
        assert_eq!(aggregate_native(&[&a, &b]), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn single_model_identity() {
        let a = vec![1.5f32; 100];
        assert_eq!(aggregate_native(&[&a]), a);
    }

    #[test]
    fn matches_weighted_with_equal_weights() {
        let ms: Vec<Model> = (0..5)
            .map(|i| (0..97).map(|j| (i * j) as f32 * 0.01).collect())
            .collect();
        let refs: Vec<&Model> = ms.iter().collect();
        let a = aggregate_native(&refs);
        let w = aggregate_weighted(&refs, &[1.0; 5]);
        for (x, y) in a.iter().zip(&w) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let a = vec![0.0f32, 0.0];
        let b = vec![4.0f32, 8.0];
        let m = aggregate_weighted(&[&a, &b], &[3.0, 1.0]);
        assert_eq!(m, vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "zero models")]
    fn empty_panics() {
        aggregate_native(&[]);
    }
}
