//! Native model averaging — the L3 aggregation hot path.
//!
//! An aggregator averages up to `s` models of up to ~1.75M f32 each, every
//! round. The accumulator is one flat buffer filled once and updated
//! in-place with a chunked slice add (8 independent lanes per step) that
//! LLVM turns into packed SIMD; element order within each lane is
//! preserved, so results are bit-identical to the sequential loop.
//! `benches/hotpaths.rs` compares it against the XLA/Pallas path and a
//! naive index-per-element loop (see EXPERIMENTS.md §Perf for numbers).

use super::task::Model;

/// Lanes per unrolled step of the accumulate/scale loops.
const CHUNK: usize = 8;

/// `acc[i] += src[i]` over equal-length slices, in `CHUNK`-wide strips so
/// the bounds checks hoist and the body auto-vectorizes. Per-element
/// accumulation order is unchanged (each element still adds the same
/// sequence of values), so this is bit-compatible with the scalar loop.
#[inline]
fn add_assign_chunked(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    let mut a = acc.chunks_exact_mut(CHUNK);
    let mut s = src.chunks_exact(CHUNK);
    for (ca, cs) in (&mut a).zip(&mut s) {
        for (x, &y) in ca.iter_mut().zip(cs.iter()) {
            *x += y;
        }
    }
    for (x, &y) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *x += y;
    }
}

/// `acc[i] *= k` in the same chunked shape.
#[inline]
fn scale_chunked(acc: &mut [f32], k: f32) {
    let mut a = acc.chunks_exact_mut(CHUNK);
    for ca in &mut a {
        for x in ca {
            *x *= k;
        }
    }
    for x in a.into_remainder() {
        *x *= k;
    }
}

/// Mean of `models` (all same length, at least one). Allocates exactly one
/// output buffer and accumulates into it in place.
pub fn aggregate_native(models: &[&Model]) -> Model {
    assert!(!models.is_empty(), "aggregate of zero models");
    let n = models[0].len();
    // One allocation + one memcpy (no redundant zero-fill).
    let mut acc = models[0].to_vec();
    for m in &models[1..] {
        assert_eq!(m.len(), n, "model length mismatch");
        add_assign_chunked(&mut acc, m);
    }
    scale_chunked(&mut acc, 1.0 / models.len() as f32);
    acc
}

/// Weighted mean (FedAvg-style weighting by sample counts, available for
/// extensions; the paper's MoDeST uses the unweighted mean).
pub fn aggregate_weighted(models: &[&Model], weights: &[f32]) -> Model {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty());
    let total: f32 = weights.iter().sum();
    assert!(total > 0.0, "zero total weight");
    let n = models[0].len();
    let mut acc = vec![0f32; n];
    for (m, &w) in models.iter().zip(weights) {
        let scale = w / total;
        for (a, &b) in acc.iter_mut().zip(m.iter()) {
            *a += scale * b;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_two() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 2.0, 1.0];
        assert_eq!(aggregate_native(&[&a, &b]), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn single_model_identity() {
        let a = vec![1.5f32; 100];
        assert_eq!(aggregate_native(&[&a]), a);
    }

    #[test]
    fn matches_sequential_reference_bitwise() {
        // The chunked kernel must reproduce the plain sequential
        // accumulate+scale exactly, including on a non-multiple-of-CHUNK
        // tail — same-seed session fingerprints depend on it.
        let ms: Vec<Model> = (0..7)
            .map(|i| (0..1003).map(|j| ((i * 31 + j) as f32).sin()).collect())
            .collect();
        let refs: Vec<&Model> = ms.iter().collect();
        let mut expect = refs[0].clone();
        for m in &refs[1..] {
            for (a, &b) in expect.iter_mut().zip(m.iter()) {
                *a += b;
            }
        }
        let inv = 1.0 / refs.len() as f32;
        for a in &mut expect {
            *a *= inv;
        }
        let got = aggregate_native(&refs);
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            expect.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn matches_weighted_with_equal_weights() {
        let ms: Vec<Model> = (0..5)
            .map(|i| (0..97).map(|j| (i * j) as f32 * 0.01).collect())
            .collect();
        let refs: Vec<&Model> = ms.iter().collect();
        let a = aggregate_native(&refs);
        let w = aggregate_weighted(&refs, &[1.0; 5]);
        for (x, y) in a.iter().zip(&w) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let a = vec![0.0f32, 0.0];
        let b = vec![4.0f32, 8.0];
        let m = aggregate_weighted(&[&a, &b], &[3.0, 1.0]);
        assert_eq!(m, vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "zero models")]
    fn empty_panics() {
        aggregate_native(&[]);
    }
}
