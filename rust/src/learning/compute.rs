//! Per-node compute-time model: heterogeneous device speeds.
//!
//! The paper's Fig. 4 discussion attributes longer rounds at larger `s` to
//! "slower nodes with higher individual training times" entering the
//! sample; we model that with a per-node speed factor drawn log-normally
//! around 1 (bounded), multiplying a base per-batch training time.

use crate::sim::{SimRng, SimTime};
use crate::NodeId;

#[derive(Debug, Clone)]
pub struct ComputeModel {
    /// Base seconds per training batch on a speed-1 node.
    pub base_batch_s: f64,
    /// Per-node multiplicative speed factors (>= min_factor).
    factors: Vec<f64>,
    /// Seconds of fixed per-round overhead (model (de)serialization etc.).
    pub round_overhead_s: f64,
}

impl ComputeModel {
    /// Draw factors for `nodes` devices: lognormal(sigma) clamped to
    /// [0.5, 4.0] — a slow phone is ~4x a fast one, matching the spread
    /// the paper's cluster emulation produces.
    pub fn heterogeneous(
        nodes: usize,
        base_batch_s: f64,
        sigma: f64,
        rng: &mut SimRng,
    ) -> ComputeModel {
        let factors = (0..nodes)
            .map(|_| (sigma * rng.next_gaussian()).exp().clamp(0.5, 4.0))
            .collect();
        ComputeModel { base_batch_s, factors, round_overhead_s: 0.05 }
    }

    /// All nodes identical (tests, microbenches).
    pub fn uniform(nodes: usize, base_batch_s: f64) -> ComputeModel {
        ComputeModel {
            base_batch_s,
            factors: vec![1.0; nodes],
            round_overhead_s: 0.05,
        }
    }

    pub fn ensure_nodes(&mut self, nodes: usize, rng: &mut SimRng) {
        while self.factors.len() < nodes {
            self.factors.push((0.35 * rng.next_gaussian()).exp().clamp(0.5, 4.0));
        }
    }

    pub fn factor(&self, node: NodeId) -> f64 {
        self.factors[node as usize]
    }

    /// Virtual duration of `batches` local training batches on `node`.
    pub fn train_time(&self, node: NodeId, batches: u32) -> SimTime {
        SimTime::from_secs_f64(
            self.round_overhead_s + self.base_batch_s * self.factor(node) * batches as f64,
        )
    }

    /// Virtual duration of aggregating `k` models of `bytes` each
    /// (memory-bandwidth bound, tiny next to training but not zero).
    pub fn aggregate_time(&self, node: NodeId, k: usize, bytes: u64) -> SimTime {
        // ~4 GB/s effective single-core streaming for read+accumulate.
        let secs = (k as f64 * bytes as f64) / 4e9;
        SimTime::from_secs_f64(secs * self.factor(node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_factors_are_one() {
        let m = ComputeModel::uniform(5, 0.02);
        for n in 0..5 {
            assert_eq!(m.factor(n), 1.0);
        }
        let t = m.train_time(0, 10);
        assert!((t.as_secs_f64() - (0.05 + 0.2)).abs() < 1e-9);
    }

    #[test]
    fn heterogeneous_bounded_and_spread() {
        let mut rng = SimRng::new(1);
        let m = ComputeModel::heterogeneous(500, 0.02, 0.35, &mut rng);
        let min = (0..500u32).map(|n| m.factor(n)).fold(f64::MAX, f64::min);
        let max = (0..500u32).map(|n| m.factor(n)).fold(0.0, f64::max);
        assert!(min >= 0.5 && max <= 4.0);
        assert!(max / min > 1.5, "no heterogeneity: {min}..{max}");
    }

    #[test]
    fn slower_nodes_take_longer() {
        let mut rng = SimRng::new(2);
        let m = ComputeModel::heterogeneous(100, 0.02, 0.35, &mut rng);
        let (mut slow, mut fast) = (0u32, 0u32);
        for n in 0..100u32 {
            if m.factor(n) > m.factor(slow) {
                slow = n;
            }
            if m.factor(n) < m.factor(fast) {
                fast = n;
            }
        }
        assert!(m.train_time(slow, 20) > m.train_time(fast, 20));
    }

    #[test]
    fn aggregate_time_scales_with_models() {
        let m = ComputeModel::uniform(2, 0.02);
        let one = m.aggregate_time(0, 1, 1_000_000);
        let ten = m.aggregate_time(0, 10, 1_000_000);
        assert!(ten.as_secs_f64() > 5.0 * one.as_secs_f64());
    }

    #[test]
    fn ensure_nodes_grows() {
        let mut rng = SimRng::new(3);
        let mut m = ComputeModel::uniform(2, 0.02);
        m.ensure_nodes(10, &mut rng);
        assert!(m.factor(9) >= 0.5);
    }
}
