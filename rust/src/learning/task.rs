//! The `Task` trait: what a protocol needs from a learning workload.

use anyhow::Result;

use crate::NodeId;

/// A model is a flat f32 vector — the same interchange format the AOT'd
/// executables use, so protocols move models around without copies or
/// reshapes.
pub type Model = Vec<f32>;

/// Result of evaluating a model on the global test set.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    /// Task metric: accuracy in [0,1] for classification/LM, MSE for
    /// recommendation (lower is better there — see `metric_is_accuracy`).
    pub metric: f64,
    /// Mean loss over the test set.
    pub loss: f64,
}

/// A learning workload: model + private per-node shards + test set.
pub trait Task {
    /// Flat parameter count.
    fn param_count(&self) -> usize;

    /// Bytes of one serialized model (drives the traffic model).
    fn model_bytes(&self) -> u64;

    /// The shared initial model (paper Alg. 4: RANDOMMODEL(), same at
    /// every node since hyperparameters are distributed out-of-band).
    fn init_model(&self) -> Model;

    /// One local epoch (paper: E=1, B=20) of SGD on `node`'s shard.
    ///
    /// `seed` must make batch order deterministic per (session, node,
    /// round). Returns the updated model, mean train loss, and the number
    /// of batches run (drives the compute-time model).
    fn local_update(
        &mut self,
        model: &Model,
        node: NodeId,
        seed: u64,
    ) -> Result<(Model, f32, u32)>;

    /// Batches in one local epoch for `node` (for time estimates without
    /// running the update).
    fn batches_per_epoch(&self, node: NodeId) -> u32;

    /// Evaluate on the global held-out test set.
    fn evaluate(&mut self, model: &Model) -> Result<EvalResult>;

    /// Average a set of models (Alg. 4 `AVG(Θ)`).
    fn aggregate(&mut self, models: &[&Model]) -> Result<Model>;

    /// `true` if `metric` is an accuracy (higher better), `false` for MSE.
    fn metric_is_accuracy(&self) -> bool {
        true
    }

    /// Human name of the metric for logs/CSV headers.
    fn metric_name(&self) -> &'static str {
        if self.metric_is_accuracy() {
            "accuracy"
        } else {
            "mse"
        }
    }
}
