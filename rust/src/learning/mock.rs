//! Closed-form mock task for protocol testing without PJRT.
//!
//! The model is a point in R^d; each node's "data" is a private optimum
//! `w_node = w* + heterogeneity * delta_node`; a local epoch runs a few
//! noisy gradient steps of the quadratic `||w - w_node||^2`. Averaging
//! across nodes pulls toward `w*` exactly like FL/DL averaging does, so
//! protocol-level behaviour (convergence ordering, variance between local
//! models, effect of sampling) is faithfully miniaturized and has a
//! closed-form check: metric = 1 / (1 + ||w - w*||^2) in (0, 1].

use anyhow::Result;

use crate::sim::SimRng;
use crate::NodeId;

use super::task::{EvalResult, Model, Task};

#[derive(Debug, Clone)]
pub struct MockTask {
    dim: usize,
    optimum: Vec<f32>,
    node_delta: Vec<Vec<f32>>,
    batches: u32,
    lr: f32,
    noise: f32,
    /// How far node optima sit from the global one (non-IIDness knob).
    pub heterogeneity: f32,
}

impl MockTask {
    pub fn new(nodes: usize, dim: usize, heterogeneity: f32, seed: u64) -> MockTask {
        let mut rng = SimRng::new(seed);
        let optimum = (0..dim).map(|_| rng.next_gaussian() as f32).collect();
        let mut node_delta: Vec<Vec<f32>> = (0..nodes)
            .map(|_| (0..dim).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        // Center the deltas: the population mean of node optima IS the
        // global optimum, exactly like label-skew non-IIDness where the
        // union of shards is the global distribution. Full-participation
        // averaging then converges to w*; sampled averaging fluctuates
        // around it with variance ~ heterogeneity^2 * dim / s.
        for d in 0..dim {
            let mean: f32 =
                node_delta.iter().map(|v| v[d]).sum::<f32>() / nodes.max(1) as f32;
            for v in node_delta.iter_mut() {
                v[d] -= mean;
            }
        }
        MockTask {
            dim,
            optimum,
            node_delta,
            batches: 5,
            lr: 0.3,
            noise: 0.02,
            heterogeneity,
        }
    }

    pub fn ensure_nodes(&mut self, nodes: usize, seed: u64) {
        let mut rng = SimRng::new(seed ^ 0x6d6f636b);
        while self.node_delta.len() < nodes {
            self.node_delta
                .push((0..self.dim).map(|_| rng.next_gaussian() as f32).collect());
        }
    }

    /// Squared distance to the global optimum (the mock's "loss").
    pub fn sq_dist(&self, model: &Model) -> f64 {
        model
            .iter()
            .zip(&self.optimum)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum()
    }
}

impl Task for MockTask {
    fn param_count(&self) -> usize {
        self.dim
    }

    fn model_bytes(&self) -> u64 {
        (self.dim * 4) as u64
    }

    fn init_model(&self) -> Model {
        vec![0.0; self.dim]
    }

    fn local_update(
        &mut self,
        model: &Model,
        node: NodeId,
        seed: u64,
    ) -> Result<(Model, f32, u32)> {
        let delta = &self.node_delta[node as usize];
        let mut rng = SimRng::new(seed);
        let mut w = model.clone();
        let mut last_loss = 0f32;
        for _ in 0..self.batches {
            last_loss = 0.0;
            for i in 0..self.dim {
                let target = self.optimum[i] + self.heterogeneity * delta[i];
                let g = w[i] - target + self.noise * rng.next_gaussian() as f32;
                last_loss += (w[i] - target) * (w[i] - target);
                w[i] -= self.lr * g;
            }
            last_loss /= self.dim as f32;
        }
        Ok((w, last_loss, self.batches))
    }

    fn batches_per_epoch(&self, _node: NodeId) -> u32 {
        self.batches
    }

    fn evaluate(&mut self, model: &Model) -> Result<EvalResult> {
        let d = self.sq_dist(model);
        Ok(EvalResult { metric: 1.0 / (1.0 + d), loss: d })
    }

    fn aggregate(&mut self, models: &[&Model]) -> Result<Model> {
        Ok(super::agg::aggregate_native(models))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_update_approaches_node_optimum() {
        let mut t = MockTask::new(4, 16, 0.5, 7);
        let m = t.init_model();
        let (m1, loss1, batches) = t.local_update(&m, 0, 1).unwrap();
        let (_, loss2, _) = t.local_update(&m1, 0, 2).unwrap();
        assert_eq!(batches, 5);
        assert!(loss2 < loss1, "{loss2} !< {loss1}");
    }

    #[test]
    fn averaging_rounds_converge_to_global_optimum() {
        // Mini-FedAvg over the mock: metric should approach 1.
        let mut t = MockTask::new(8, 16, 0.5, 7);
        let mut global = t.init_model();
        for round in 0..30 {
            let locals: Vec<Model> = (0..8u32)
                .map(|n| t.local_update(&global, n, round * 100 + n as u64).unwrap().0)
                .collect();
            let refs: Vec<&Model> = locals.iter().collect();
            global = t.aggregate(&refs).unwrap();
        }
        let m = t.evaluate(&global).unwrap();
        assert!(m.metric > 0.9, "metric {}", m.metric);
    }

    #[test]
    fn heterogeneity_slows_single_node_training() {
        // Training on one node only converges to ITS optimum, not w*.
        let mut t = MockTask::new(4, 16, 2.0, 9);
        let mut m = t.init_model();
        for round in 0..30 {
            m = t.local_update(&m, 0, round).unwrap().0;
        }
        let e = t.evaluate(&m).unwrap();
        assert!(e.metric < 0.5, "one-node training should miss w*: {}", e.metric);
    }

    #[test]
    fn eval_metric_in_unit_interval() {
        let mut t = MockTask::new(2, 8, 0.1, 3);
        let e = t.evaluate(&t.init_model()).unwrap();
        assert!(e.metric > 0.0 && e.metric <= 1.0);
    }
}
