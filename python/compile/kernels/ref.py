"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

The pytest/hypothesis suite asserts ``assert_allclose(kernel(x), ref(x))``
over swept shapes, so any tiling or masking bug in the kernels shows up as a
numeric diff here rather than as silent training degradation downstream.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Reference for kernels.dense.dense: ``x @ w + b``."""
    return x @ w + b


def dense_dx(g: jax.Array, w: jax.Array) -> jax.Array:
    """Reference backward wrt x."""
    return g @ w.T


def dense_dw(x: jax.Array, g: jax.Array) -> jax.Array:
    """Reference backward wrt w."""
    return x.T @ g


def dense_db(g: jax.Array) -> jax.Array:
    """Reference backward wrt b."""
    return jnp.sum(g, axis=0)


def sgd_update(
    params: jax.Array,
    velocity: jax.Array,
    grads: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Reference for kernels.sgd.sgd_update."""
    v = mu * velocity + grads
    return params - lr * v, v


def masked_mean(
    stack: jax.Array, mask: jax.Array, count: jax.Array
) -> jax.Array:
    """Reference for kernels.avg.masked_mean."""
    return (mask[:, None] * stack).sum(axis=0) / count


__all__ = ["dense", "dense_dx", "dense_dw", "dense_db", "sgd_update", "masked_mean"]
