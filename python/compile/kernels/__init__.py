"""Layer-1 Pallas kernels for the MoDeST reproduction.

These are the compute hot spots of the system, written as Pallas kernels
(interpret=True so they lower to plain HLO ops executable on the CPU PJRT
client; see DESIGN.md §Hardware-Adaptation for the TPU tiling story):

* :mod:`dense`  — tiled matmul + bias, forward and backward (custom_vjp).
  The per-round training hot spot (every local SGD step of every sampled
  trainer runs through it).
* :mod:`sgd`    — fused (momentum-)SGD update on the flat parameter vector.
* :mod:`avg`    — masked mean over a stack of flat models: the aggregator
  hot spot (Alg. 4 line 21, ``AVG(Θ)``).

``ref.py`` holds the pure-jnp oracles used by the pytest/hypothesis suite.
"""

from . import avg, dense, ref, sgd  # noqa: F401

__all__ = ["avg", "dense", "ref", "sgd"]
