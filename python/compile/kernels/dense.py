"""Tiled dense (matmul + bias) layer as Pallas kernels, fwd + bwd.

This is the training hot spot: every hidden layer of every model variant
routes through :func:`dense`, both forward and (via ``jax.custom_vjp``)
backward, so the whole local-SGD step of a sampled trainer is dominated by
these three kernels.

TPU tiling story (DESIGN.md §Hardware-Adaptation): blocks are chosen as the
largest divisor of each dimension capped at MXU-friendly 128. The grid walks
output tiles; the contraction dimension is kept resident per tile (all our
model widths fit VMEM comfortably — see the §Perf VMEM table). On CPU we run
interpret=True, which lowers to plain HLO so the AOT'd module executes on the
PJRT CPU client from rust.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def target() -> str:
    """Lowering target: "tpu" tiles for the MXU/VMEM; "cpu" (default here)
    uses large blocks because interpret-mode grids materialize full-array
    copies per grid step on the CPU backend (measured 3.9ms/step on a 1.75M
    param model — see EXPERIMENTS.md §Perf L1 iteration 1)."""
    return os.environ.get("MODEST_PALLAS_TARGET", "cpu")


def block_cap() -> int:
    # 128 matches the MXU systolic array edge and keeps worst-case VMEM
    # residency (x, w, o tiles + K-strip) under ~2 MB; on CPU-interpret we
    # want as few grid steps as possible.
    return 128 if target() == "tpu" else 2048


def _tile(dim: int, cap: int | None = None) -> int:
    """Largest divisor of ``dim`` that is <= cap (>=1 always exists)."""
    cap = block_cap() if cap is None else cap
    t = min(dim, cap)
    while dim % t != 0:
        t -= 1
    return t


def _matmul_bias_kernel(x_ref, w_ref, b_ref, o_ref):
    """o = x @ w + b over one (bm, bn) output tile; K resident."""
    o_ref[...] = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )


def _dx_kernel(g_ref, w_ref, o_ref):
    """dx = g @ w.T over one (bm, bd) tile; N resident."""
    o_ref[...] = jnp.dot(
        g_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    )


def _dw_kernel(x_ref, g_ref, o_ref):
    """dw = x.T @ g over one (bd, bn) tile; M resident."""
    o_ref[...] = jnp.dot(
        x_ref[...].T, g_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def _dense_fwd_pallas(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn = _tile(m), _tile(n)
    return pl.pallas_call(
        _matmul_bias_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)


def _dense_dx_pallas(g: jax.Array, w: jax.Array) -> jax.Array:
    m, n = g.shape
    d, n2 = w.shape
    assert n == n2
    bm, bd = _tile(m), _tile(d)
    return pl.pallas_call(
        _dx_kernel,
        grid=(m // bm, d // bd),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bd, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), g.dtype),
        interpret=True,
    )(g, w)


def _dense_dw_pallas(x: jax.Array, g: jax.Array) -> jax.Array:
    m, d = x.shape
    m2, n = g.shape
    assert m == m2
    bd, bn = _tile(d), _tile(n)
    return pl.pallas_call(
        _dw_kernel,
        grid=(d // bd, n // bn),
        in_specs=[
            pl.BlockSpec((m, bd), lambda i, j: (0, i)),
            pl.BlockSpec((m, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bd, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, n), x.dtype),
        interpret=True,
    )(x, g)


@jax.custom_vjp
def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """``x @ w + b`` through the Pallas forward kernel.

    Differentiable: the VJP routes dx/dw through the Pallas backward kernels
    and db through a cheap jnp reduction.
    """
    return _dense_fwd_pallas(x, w, b)


def _dense_vjp_fwd(x, w, b):
    return _dense_fwd_pallas(x, w, b), (x, w)


def _dense_vjp_bwd(res, g):
    x, w = res
    return _dense_dx_pallas(g, w), _dense_dw_pallas(x, g), jnp.sum(g, axis=0)


dense.defvjp(_dense_vjp_fwd, _dense_vjp_bwd)


__all__ = ["dense", "_tile"]
