"""Masked model averaging — the aggregator hot spot (Alg. 4, ``AVG(Θ)``).

An aggregator in MoDeST receives between ``ceil(sf*s)`` and ``s`` updated
models per round and averages them. XLA needs static shapes, so the AOT'd
module is compiled for a fixed ``smax`` rows; the rust side zero-pads the
stack and passes a 0/1 mask plus the live count:

    out[p] = sum_j mask[j] * stack[j, p] / count

The masked mean is computed as a single ``mask @ stack`` matvec — on TPU an
MXU matvec with the mask resident, streaming ``(smax, T)`` tiles through
VMEM (grid along the flat parameter axis). On CPU-interpret the whole stack
is one block: grids copy full arrays per step on that backend (see
EXPERIMENTS.md §Perf, L1 iteration 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dense import target

_TPU_TILE = 8 * 1024


def _avg_kernel(s_ref, m_ref, c_ref, o_ref):
    # (smax,) @ (smax, T) -> (T,) masked sum, then scale by 1/count.
    o_ref[...] = jnp.dot(
        m_ref[...], s_ref[...], preferred_element_type=jnp.float32
    ) * (1.0 / c_ref[0])


def masked_mean(
    stack: jax.Array, mask: jax.Array, count: jax.Array
) -> jax.Array:
    """Masked mean over the first axis of ``stack [smax, P]``.

    ``mask`` is an f32 0/1 vector of length smax; ``count`` a positive scalar
    (the number of live rows). Rows with mask 0 are ignored.
    """
    smax, p = stack.shape
    assert mask.shape == (smax,)
    c1 = jnp.reshape(count.astype(jnp.float32), (1,))

    if target() != "tpu":
        return pl.pallas_call(
            _avg_kernel,
            out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
            interpret=True,
        )(stack, mask, c1)

    tile = _TPU_TILE
    pad = (-p) % tile
    sp = jnp.pad(stack, ((0, 0), (0, pad)))
    n = sp.shape[1] // tile
    out = pl.pallas_call(
        _avg_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((smax, tile), lambda i: (0, i)),
            pl.BlockSpec((smax,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sp.shape[1],), jnp.float32),
        interpret=True,
    )(sp, mask, c1)
    return out[:p]


__all__ = ["masked_mean"]
