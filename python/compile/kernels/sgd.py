"""Fused (momentum-)SGD update on the flat parameter vector, as a Pallas kernel.

The whole model is a single flat f32 vector (the interchange format with the
rust coordinator), so the optimizer update is one streaming pass:

    v' = mu * v + g
    p' = p - lr * v'

With ``mu == 0`` this degenerates to plain SGD (``p - lr*g``) regardless of
the incoming velocity, which lets every model variant share one train-step
signature (the paper uses momentum only for CIFAR10, plain SGD elsewhere).

Target-dependent structure (see ``dense.target``):

* ``tpu`` — tiled along the flat vector in (8x1024)-f32 strips: each grid
  step streams one strip HBM->VMEM, fuses the two FMAs on the VPU, and
  writes both outputs back; VMEM residency is 5 strips = 160 KB.
* ``cpu`` (default) — a single whole-vector block. Interpret-mode grids
  materialize full-array copies per grid step on the CPU backend (measured
  ~3.9 ms/step x 215 steps = 838 ms on the FEMNIST-sized model), so the
  CPU lowering uses one grid step: 6 ms for the same update
  (EXPERIMENTS.md §Perf, L1 iteration 1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dense import target

# TPU strip: 8 sublanes x 1024 lanes of f32 — a full VREG tile times 8.
_TPU_TILE = 8 * 1024


def _sgd_kernel(p_ref, v_ref, g_ref, lr_ref, mu_ref, p_out_ref, v_out_ref):
    v_new = mu_ref[0] * v_ref[...] + g_ref[...]
    v_out_ref[...] = v_new
    p_out_ref[...] = p_ref[...] - lr_ref[0] * v_new


def sgd_update(
    params: jax.Array,
    velocity: jax.Array,
    grads: jax.Array,
    lr: jax.Array,
    mu: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Fused momentum-SGD step over flat ``[P]`` vectors.

    Returns ``(new_params, new_velocity)``. ``lr`` and ``mu`` are scalars.
    """
    (p,) = params.shape
    assert velocity.shape == (p,) and grads.shape == (p,)
    lr1 = jnp.reshape(lr.astype(jnp.float32), (1,))
    mu1 = jnp.reshape(mu.astype(jnp.float32), (1,))

    if target() != "tpu":
        # Single-block lowering: no grid, refs see the whole vectors.
        new_p, new_v = pl.pallas_call(
            _sgd_kernel,
            out_shape=[
                jax.ShapeDtypeStruct((p,), jnp.float32),
                jax.ShapeDtypeStruct((p,), jnp.float32),
            ],
            interpret=True,
        )(params, velocity, grads, lr1, mu1)
        return new_p, new_v

    tile = _TPU_TILE
    pad = (-p) % tile
    pp = jnp.pad(params, (0, pad))
    vp = jnp.pad(velocity, (0, pad))
    gp = jnp.pad(grads, (0, pad))
    n = pp.shape[0] // tile
    new_p, new_v = pl.pallas_call(
        _sgd_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(pp.shape, jnp.float32),
            jax.ShapeDtypeStruct(vp.shape, jnp.float32),
        ],
        interpret=True,
    )(pp, vp, gp, lr1, mu1)
    return new_p[:p], new_v[:p]


__all__ = ["sgd_update"]
