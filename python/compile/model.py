"""Layer-2: JAX model definitions for every learning task in the paper.

Table 3 of the paper evaluates four tasks (CIFAR10 / CelebA / FEMNIST image
classification, MovieLens matrix factorization); we add a small causal
transformer LM for the end-to-end example. Real image datasets are replaced
by seeded synthetic feature tasks generated on the rust side (DESIGN.md §3);
what matters for the systems results is that the **parameter byte counts
match the paper's Table 3**, which they do (see ``VARIANTS``).

Interchange with the rust coordinator is a single flat f32 vector:

    train_step(params[P], vel[P], x, y, lr, mu) -> (params'[P], vel'[P], loss)
    eval_step(params[P], x, y)                  -> (metric_sum, loss_sum)
    avg(stack[smax,P], mask[smax], count)       -> params[P]

``mu=0`` makes the momentum step exact plain SGD, so one signature serves
all variants. Hidden layers route through the Pallas ``dense`` kernel
(fwd+bwd), the optimizer through the fused Pallas ``sgd_update``, and
aggregation through the Pallas ``masked_mean`` — the three L1 hot spots.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.avg import masked_mean
from .kernels.dense import dense
from .kernels.sgd import sgd_update

# --------------------------------------------------------------------------
# Flat parameter plumbing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Ordered (name, shape) list defining the flat layout of a model."""

    entries: tuple[tuple[str, tuple[int, ...]], ...]

    @property
    def sizes(self) -> list[int]:
        return [int(np.prod(s)) for _, s in self.entries]

    @property
    def total(self) -> int:
        return sum(self.sizes)

    def unflatten(self, flat: jax.Array) -> dict[str, jax.Array]:
        out, off = {}, 0
        for (name, shape), size in zip(self.entries, self.sizes):
            out[name] = flat[off : off + size].reshape(shape)
            off += size
        return out

    def flatten(self, tree: dict[str, jax.Array]) -> jax.Array:
        return jnp.concatenate(
            [tree[name].reshape(-1) for name, _ in self.entries]
        )


def _glorot(rng: np.random.Generator, shape: tuple[int, ...]) -> np.ndarray:
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    fan_out = shape[-1]
    lim = np.sqrt(6.0 / (fan_in + fan_out))
    return rng.uniform(-lim, lim, size=shape).astype(np.float32)


# --------------------------------------------------------------------------
# MLP classifier (stands in for the paper's small CNNs at equal byte size)
# --------------------------------------------------------------------------


def mlp_spec(input_dim: int, hidden: int, classes: int) -> ParamSpec:
    return ParamSpec(
        (
            ("w1", (input_dim, hidden)),
            ("b1", (hidden,)),
            ("w2", (hidden, hidden)),
            ("b2", (hidden,)),
            ("w3", (hidden, classes)),
            ("b3", (classes,)),
        )
    )


def mlp_init(spec: ParamSpec, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    parts = []
    for name, shape in spec.entries:
        if name.startswith("w"):
            parts.append(_glorot(rng, shape).reshape(-1))
        else:
            parts.append(np.zeros(int(np.prod(shape)), np.float32))
    return np.concatenate(parts)


def mlp_logits(spec: ParamSpec, flat: jax.Array, x: jax.Array) -> jax.Array:
    p = spec.unflatten(flat)
    h = jax.nn.relu(dense(x, p["w1"], p["b1"]))
    h = jax.nn.relu(dense(h, p["w2"], p["b2"]))
    return dense(h, p["w3"], p["b3"])


def _xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def mlp_loss(spec: ParamSpec, flat: jax.Array, x: jax.Array, y: jax.Array):
    return _xent(mlp_logits(spec, flat, x), y)


def mlp_eval(spec: ParamSpec, flat: jax.Array, x: jax.Array, y: jax.Array):
    logits = mlp_logits(spec, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss_sum = -jnp.sum(jnp.take_along_axis(logp, y[:, None], axis=-1))
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return correct, loss_sum


# --------------------------------------------------------------------------
# Matrix factorization (MovieLens task, one-user-one-node)
# --------------------------------------------------------------------------


def matfact_spec(users: int, items: int, dim: int) -> ParamSpec:
    return ParamSpec(
        (
            ("u_emb", (users, dim)),
            ("i_emb", (items, dim)),
            ("u_bias", (users,)),
            ("i_bias", (items,)),
            ("g_bias", (1,)),
        )
    )


def matfact_init(spec: ParamSpec, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    parts = []
    for name, shape in spec.entries:
        n = int(np.prod(shape))
        if name.endswith("emb"):
            parts.append((0.1 * rng.standard_normal(n)).astype(np.float32))
        else:
            parts.append(np.zeros(n, np.float32))
    return np.concatenate(parts)


def matfact_predict(spec: ParamSpec, flat: jax.Array, x: jax.Array):
    """x is int32 [B, 2] of (user, item) indices."""
    p = spec.unflatten(flat)
    u, i = x[:, 0], x[:, 1]
    dot = jnp.sum(p["u_emb"][u] * p["i_emb"][i], axis=-1)
    return p["g_bias"][0] + p["u_bias"][u] + p["i_bias"][i] + dot


_MF_REG = 1e-4


def matfact_loss(spec: ParamSpec, flat: jax.Array, x: jax.Array, y: jax.Array):
    pred = matfact_predict(spec, flat, x)
    p = spec.unflatten(flat)
    u, i = x[:, 0], x[:, 1]
    reg = _MF_REG * (
        jnp.sum(p["u_emb"][u] ** 2) + jnp.sum(p["i_emb"][i] ** 2)
    )
    return jnp.mean((pred - y) ** 2) + reg / x.shape[0]


def matfact_eval(spec: ParamSpec, flat: jax.Array, x: jax.Array, y: jax.Array):
    pred = matfact_predict(spec, flat, x)
    se = jnp.sum((pred - y) ** 2)
    return se, se  # metric and loss are both squared-error sums (MSE task)


# --------------------------------------------------------------------------
# Tiny causal transformer LM (end-to-end example workload)
# --------------------------------------------------------------------------


def transformer_spec(
    vocab: int, d: int, layers: int, d_ff: int, max_t: int
) -> ParamSpec:
    entries: list[tuple[str, tuple[int, ...]]] = [
        ("tok_emb", (vocab, d)),
        ("pos_emb", (max_t, d)),
    ]
    for l in range(layers):
        entries += [
            (f"l{l}.ln1_g", (d,)),
            (f"l{l}.ln1_b", (d,)),
            (f"l{l}.wqkv", (d, 3 * d)),
            (f"l{l}.bqkv", (3 * d,)),
            (f"l{l}.wo", (d, d)),
            (f"l{l}.bo", (d,)),
            (f"l{l}.ln2_g", (d,)),
            (f"l{l}.ln2_b", (d,)),
            (f"l{l}.w1", (d, d_ff)),
            (f"l{l}.b1", (d_ff,)),
            (f"l{l}.w2", (d_ff, d)),
            (f"l{l}.b2", (d,)),
        ]
    entries += [("lnf_g", (d,)), ("lnf_b", (d,)), ("head", (d, vocab))]
    return ParamSpec(tuple(entries))


def transformer_init(spec: ParamSpec, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    parts = []
    for name, shape in spec.entries:
        n = int(np.prod(shape))
        if "ln" in name and name.endswith("_g"):
            parts.append(np.ones(n, np.float32))
        elif name.endswith("_b") or ".b" in name:
            parts.append(np.zeros(n, np.float32))
        elif "emb" in name:
            parts.append((0.02 * rng.standard_normal(n)).astype(np.float32))
        else:
            parts.append(_glorot(rng, shape).reshape(-1))
    return np.concatenate(parts)


def _layer_norm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def transformer_logits(
    spec: ParamSpec,
    flat: jax.Array,
    x: jax.Array,
    *,
    d: int,
    layers: int,
    heads: int,
) -> jax.Array:
    """x is int32 [B, T] tokens; returns [B, T, vocab] logits."""
    p = spec.unflatten(flat)
    b, t = x.shape
    h = p["tok_emb"][x] + p["pos_emb"][:t]
    hd = d // heads
    mask = jnp.tril(jnp.ones((t, t), bool))
    for l in range(layers):
        pre = _layer_norm(h, p[f"l{l}.ln1_g"], p[f"l{l}.ln1_b"])
        qkv = dense(pre.reshape(b * t, d), p[f"l{l}.wqkv"], p[f"l{l}.bqkv"])
        q, k, v = jnp.split(qkv.reshape(b, t, 3 * d), 3, axis=-1)
        q = q.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
        att = jnp.where(mask[None, None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(b * t, d)
        h = h + dense(o, p[f"l{l}.wo"], p[f"l{l}.bo"]).reshape(b, t, d)
        pre = _layer_norm(h, p[f"l{l}.ln2_g"], p[f"l{l}.ln2_b"])
        ff = jax.nn.gelu(
            dense(pre.reshape(b * t, d), p[f"l{l}.w1"], p[f"l{l}.b1"])
        )
        ff = dense(ff, p[f"l{l}.w2"], p[f"l{l}.b2"])
        h = h + ff.reshape(b, t, d)
    h = _layer_norm(h, p["lnf_g"], p["lnf_b"])
    return h @ p["head"]


def transformer_loss(spec, flat, x, y, *, d, layers, heads):
    logits = transformer_logits(spec, flat, x, d=d, layers=layers, heads=heads)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)
    return jnp.mean(nll)


def transformer_eval(spec, flat, x, y, *, d, layers, heads):
    logits = transformer_logits(spec, flat, x, d=d, layers=layers, heads=heads)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)
    correct = jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return correct, jnp.sum(nll)


# --------------------------------------------------------------------------
# Unified step builders
# --------------------------------------------------------------------------


def make_train_step(loss_fn: Callable) -> Callable:
    """Wrap a loss into the uniform (params, vel, x, y, lr, mu) signature."""

    def train_step(params, vel, x, y, lr, mu):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        new_p, new_v = sgd_update(params, vel, grads, lr, mu)
        return new_p, new_v, loss

    return train_step


def make_avg_step() -> Callable:
    """(stack[smax,P], mask[smax], count) -> (avg[P],) via the Pallas kernel."""

    def avg_step(stack, mask, count):
        return (masked_mean(stack, mask, count),)

    return avg_step


# --------------------------------------------------------------------------
# Variant registry — byte sizes match the paper's Table 3
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Variant:
    """One learning task: specs, step fns, and the paper's hyperparameters."""

    name: str
    kind: str  # classifier | matfact | lm
    spec: ParamSpec
    init: Callable[[int], np.ndarray]
    loss: Callable
    evaluate: Callable
    train_x: tuple[tuple[int, ...], str]  # (shape, dtype)
    train_y: tuple[tuple[int, ...], str]
    eval_x: tuple[tuple[int, ...], str]
    eval_y: tuple[tuple[int, ...], str]
    lr: float
    momentum: float
    nodes: int  # paper Table 3 network size
    smax: int = 16
    meta: dict | None = None

    @property
    def param_count(self) -> int:
        return self.spec.total


_B = 20  # paper batch size (Section 4.2)
_EVAL_B = 256


def _classifier_variant(
    name: str, hidden: int, classes: int, lr: float, momentum: float, nodes: int
) -> Variant:
    input_dim = 128
    spec = mlp_spec(input_dim, hidden, classes)
    return Variant(
        name=name,
        kind="classifier",
        spec=spec,
        init=lambda seed: mlp_init(spec, seed),
        loss=lambda flat, x, y: mlp_loss(spec, flat, x, y),
        evaluate=lambda flat, x, y: mlp_eval(spec, flat, x, y),
        train_x=((_B, input_dim), "f32"),
        train_y=((_B,), "i32"),
        eval_x=((_EVAL_B, input_dim), "f32"),
        eval_y=((_EVAL_B,), "i32"),
        lr=lr,
        momentum=momentum,
        nodes=nodes,
        meta={"input_dim": input_dim, "hidden": hidden, "classes": classes},
    )


def _matfact_variant() -> Variant:
    users, items, dim = 610, 9724, 20
    spec = matfact_spec(users, items, dim)
    return Variant(
        name="movielens",
        kind="matfact",
        spec=spec,
        init=lambda seed: matfact_init(spec, seed),
        loss=lambda flat, x, y: matfact_loss(spec, flat, x, y),
        evaluate=lambda flat, x, y: matfact_eval(spec, flat, x, y),
        train_x=((_B, 2), "i32"),
        train_y=((_B,), "f32"),
        eval_x=((_EVAL_B, 2), "i32"),
        eval_y=((_EVAL_B,), "f32"),
        lr=0.2,
        momentum=0.0,
        nodes=610,
        meta={"users": users, "items": items, "dim": dim},
    )


def _transformer_variant() -> Variant:
    vocab, d, layers, heads, d_ff, max_t = 64, 128, 2, 4, 512, 64
    bt = 8
    spec = transformer_spec(vocab, d, layers, d_ff, max_t)
    kw = dict(d=d, layers=layers, heads=heads)
    return Variant(
        name="transformer",
        kind="lm",
        spec=spec,
        init=lambda seed: transformer_init(spec, seed),
        loss=lambda flat, x, y: transformer_loss(spec, flat, x, y, **kw),
        evaluate=lambda flat, x, y: transformer_eval(spec, flat, x, y, **kw),
        train_x=((bt, max_t), "i32"),
        train_y=((bt, max_t), "i32"),
        eval_x=((bt, max_t), "i32"),
        eval_y=((bt, max_t), "i32"),
        lr=0.05,
        momentum=0.9,
        nodes=32,
        smax=8,
        meta={
            "vocab": vocab,
            "d": d,
            "layers": layers,
            "heads": heads,
            "d_ff": d_ff,
            "max_t": max_t,
        },
    )


def build_variants() -> dict[str, Variant]:
    """All model variants; parameter bytes track the paper's Table 3."""
    return {
        v.name: v
        for v in [
            # paper: LeNet CNN, 346 KB -> here 86,082 params = 344.3 KB
            _classifier_variant(
                "cifar10", 232, 10, lr=0.002, momentum=0.9, nodes=100
            ),
            # paper: CNN, 124 KB -> here 30,122 params = 120.5 KB
            _classifier_variant(
                "celeba", 120, 2, lr=0.001, momentum=0.0, nodes=500
            ),
            # paper: CNN, 6.7 MB -> here 1,754,430 params = 6.69 MB
            _classifier_variant(
                "femnist", 1232, 62, lr=0.004, momentum=0.0, nodes=355
            ),
            # paper: MF 827 KB -> here 217,015 params = 848 KB
            _matfact_variant(),
            # extra end-to-end workload (not in paper Table 3)
            _transformer_variant(),
        ]
    }


VARIANTS = build_variants()

__all__ = [
    "ParamSpec",
    "Variant",
    "VARIANTS",
    "build_variants",
    "make_train_step",
    "make_avg_step",
    "mlp_spec",
    "mlp_init",
    "mlp_logits",
    "mlp_loss",
    "mlp_eval",
    "matfact_spec",
    "matfact_init",
    "matfact_loss",
    "matfact_eval",
    "matfact_predict",
    "transformer_spec",
    "transformer_init",
    "transformer_logits",
    "transformer_loss",
    "transformer_eval",
]
