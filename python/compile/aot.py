"""AOT pipeline: lower every model variant to HLO text + write the manifest.

This is the only place Python runs — once, at build time (`make artifacts`).
The rust coordinator afterwards loads ``artifacts/*.hlo.txt`` via
``HloModuleProto::from_text_file`` and never touches Python again.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links) rejects (``proto.id() <=
INT_MAX``). The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/gen_hlo.py.

Artifacts per variant ``v``:

* ``{v}_train.hlo.txt`` — (params, vel, x, y, lr, mu) -> (params', vel', loss)
* ``{v}_eval.hlo.txt``  — (params, x, y) -> (metric_sum, loss_sum)
* ``{v}_avg.hlo.txt``   — (stack[smax,P], mask[smax], count) -> (params,)
* ``{v}_init.bin``      — little-endian f32 initial flat parameters
* ``manifest.json``     — shapes/dtypes/hyperparameters for the rust loader
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import VARIANTS, Variant, make_avg_step, make_train_step

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape_dtype: tuple[tuple[int, ...], str]) -> jax.ShapeDtypeStruct:
    shape, dtype = shape_dtype
    return jax.ShapeDtypeStruct(shape, _DTYPES[dtype])


def lower_variant(v: Variant) -> dict[str, str]:
    """Lower train/eval/avg for one variant; returns {kind: hlo_text}."""
    p = jax.ShapeDtypeStruct((v.param_count,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    train = make_train_step(v.loss)
    train_hlo = to_hlo_text(
        jax.jit(train).lower(
            p, p, _spec(v.train_x), _spec(v.train_y), scalar, scalar
        )
    )

    def eval_step(params, x, y):
        return v.evaluate(params, x, y)

    eval_hlo = to_hlo_text(
        jax.jit(eval_step).lower(p, _spec(v.eval_x), _spec(v.eval_y))
    )

    avg = make_avg_step()
    stack = jax.ShapeDtypeStruct((v.smax, v.param_count), jnp.float32)
    mask = jax.ShapeDtypeStruct((v.smax,), jnp.float32)
    avg_hlo = to_hlo_text(jax.jit(avg).lower(stack, mask, scalar))

    return {"train": train_hlo, "eval": eval_hlo, "avg": avg_hlo}


def _io_entry(shape_dtype: tuple[tuple[int, ...], str]) -> dict:
    shape, dtype = shape_dtype
    return {"shape": list(shape), "dtype": dtype}


def build_manifest_entry(v: Variant, files: dict[str, str], init_sha: str) -> dict:
    return {
        "name": v.name,
        "kind": v.kind,
        "param_count": v.param_count,
        "model_bytes": v.param_count * 4,
        "smax": v.smax,
        "lr": v.lr,
        "momentum": v.momentum,
        "nodes": v.nodes,
        "train_batch": v.train_x[0][0],
        "eval_batch": v.eval_x[0][0],
        "train_x": _io_entry(v.train_x),
        "train_y": _io_entry(v.train_y),
        "eval_x": _io_entry(v.eval_x),
        "eval_y": _io_entry(v.eval_y),
        "files": files,
        "init_sha256": init_sha,
        "meta": v.meta or {},
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact dir")
    ap.add_argument(
        "--variants",
        default="",
        help="comma-separated subset of variants (default: all)",
    )
    ap.add_argument("--seed", type=int, default=42, help="init param seed")
    # Kept for Makefile compatibility; ignored when --out-dir is used.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out_dir)
    if args.out is not None:
        out_dir = pathlib.Path(args.out).parent
    out_dir.mkdir(parents=True, exist_ok=True)

    wanted = [s for s in args.variants.split(",") if s]
    manifest: dict = {"seed": args.seed, "variants": {}}
    for name, v in VARIANTS.items():
        if wanted and name not in wanted:
            continue
        print(f"[aot] lowering {name} (P={v.param_count:,})", flush=True)
        hlos = lower_variant(v)
        files = {}
        for kind, text in hlos.items():
            fname = f"{name}_{kind}.hlo.txt"
            (out_dir / fname).write_text(text)
            files[kind] = fname
            print(f"[aot]   {fname}: {len(text):,} chars")
        init = v.init(args.seed).astype("<f4")
        assert init.shape == (v.param_count,)
        init_name = f"{name}_init.bin"
        (out_dir / init_name).write_bytes(init.tobytes())
        files["init"] = init_name
        sha = hashlib.sha256(init.tobytes()).hexdigest()
        manifest["variants"][name] = build_manifest_entry(v, files, sha)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"[aot] wrote manifest with {len(manifest['variants'])} variants")
    # Marker file used by the Makefile as the artifact-freshness stamp.
    (out_dir / ".stamp").write_text("ok\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
