"""AOT pipeline checks: lowering produces parseable HLO + a sound manifest."""

import json
import pathlib

import numpy as np
import pytest

from compile import aot
from compile.model import VARIANTS


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    """Run the real AOT entry point for the smallest variant."""
    out = tmp_path_factory.mktemp("artifacts")
    rc = aot.main(["--out-dir", str(out), "--variants", "celeba", "--seed", "7"])
    assert rc == 0
    return out


def test_aot_writes_all_files(small_artifacts):
    names = {p.name for p in small_artifacts.iterdir()}
    for expected in [
        "celeba_train.hlo.txt",
        "celeba_eval.hlo.txt",
        "celeba_avg.hlo.txt",
        "celeba_init.bin",
        "manifest.json",
        ".stamp",
    ]:
        assert expected in names, names


def test_hlo_text_is_hlo(small_artifacts):
    text = (small_artifacts / "celeba_train.hlo.txt").read_text()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text


def test_manifest_consistent(small_artifacts):
    m = json.loads((small_artifacts / "manifest.json").read_text())
    v = m["variants"]["celeba"]
    assert v["param_count"] == VARIANTS["celeba"].param_count
    assert v["model_bytes"] == v["param_count"] * 4
    init = np.frombuffer(
        (small_artifacts / "celeba_init.bin").read_bytes(), dtype="<f4"
    )
    assert init.shape == (v["param_count"],)
    assert v["train_x"]["shape"][0] == v["train_batch"]
    assert v["smax"] >= 1
    assert 0 < v["lr"] < 1


def test_init_bin_matches_model_init(small_artifacts):
    init = np.frombuffer(
        (small_artifacts / "celeba_init.bin").read_bytes(), dtype="<f4"
    )
    expect = VARIANTS["celeba"].init(7)
    np.testing.assert_array_equal(init, expect)


def test_lower_all_variants_smoke():
    """Every variant must lower (the full run is exercised by make artifacts)."""
    # Lowering femnist/movielens is slow; keep to the 2 cheapest here.
    for name in ["celeba", "transformer"]:
        hlos = aot.lower_variant(VARIANTS[name])
        assert set(hlos) == {"train", "eval", "avg"}
        for text in hlos.values():
            assert text.startswith("HloModule")
