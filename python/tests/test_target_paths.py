"""Both lowering targets (cpu single-block, tpu tiled) must agree with ref.

The target is chosen via MODEST_PALLAS_TARGET at trace time, so the tpu
path runs in a subprocess with the env var set (jit caches would otherwise
leak the cpu-path tracing into the comparison).
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.kernels import avg, dense, ref, sgd


def test_default_target_is_cpu():
    assert os.environ.get("MODEST_PALLAS_TARGET", "cpu") == "cpu"
    assert dense.block_cap() >= 1024


def test_cpu_path_kernels_match_ref():
    r = np.random.default_rng(0)
    p = r.standard_normal(50_000).astype(np.float32)
    v = r.standard_normal(50_000).astype(np.float32)
    g = r.standard_normal(50_000).astype(np.float32)
    gp, gv = sgd.sgd_update(p, v, g, jnp.float32(0.05), jnp.float32(0.9))
    wp, wv = ref.sgd_update(p, v, g, jnp.float32(0.05), jnp.float32(0.9))
    assert_allclose(np.asarray(gp), np.asarray(wp), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-5, atol=1e-6)

    stack = r.standard_normal((6, 30_000)).astype(np.float32)
    mask = np.array([1, 1, 1, 1, 0, 0], np.float32)
    stack[4:] = 0
    got = avg.masked_mean(stack, mask, jnp.float32(4.0))
    want = ref.masked_mean(stack, mask, jnp.float32(4.0))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


_TPU_SNIPPET = textwrap.dedent(
    """
    import os
    os.environ["MODEST_PALLAS_TARGET"] = "tpu"
    import numpy as np, jax.numpy as jnp
    from numpy.testing import assert_allclose
    from compile.kernels import avg, dense, ref, sgd

    assert dense.target() == "tpu"
    assert dense.block_cap() == 128

    r = np.random.default_rng(1)
    # sgd: tiled path with padding
    p = r.standard_normal(20_000).astype(np.float32)
    v = r.standard_normal(20_000).astype(np.float32)
    g = r.standard_normal(20_000).astype(np.float32)
    gp, gv = sgd.sgd_update(p, v, g, jnp.float32(0.1), jnp.float32(0.9))
    wp, wv = ref.sgd_update(p, v, g, jnp.float32(0.1), jnp.float32(0.9))
    assert_allclose(np.asarray(gp), np.asarray(wp), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-5, atol=1e-6)

    # avg: tiled path
    stack = r.standard_normal((4, 9_000)).astype(np.float32)
    mask = np.array([1, 1, 1, 0], np.float32)
    stack[3] = 0
    got = avg.masked_mean(stack, mask, jnp.float32(3.0))
    want = ref.masked_mean(stack, mask, jnp.float32(3.0))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    # dense: 128-tile grid path
    x = r.standard_normal((20, 256)).astype(np.float32)
    w = r.standard_normal((256, 384)).astype(np.float32)
    b = r.standard_normal(384).astype(np.float32)
    assert_allclose(
        np.asarray(dense.dense(x, w, b)), x @ w + b, rtol=1e-4, atol=1e-4
    )
    print("TPU-PATH-OK")
    """
)


def test_tpu_target_path_matches_ref_in_subprocess():
    env = dict(os.environ, MODEST_PALLAS_TARGET="tpu")
    out = subprocess.run(
        [sys.executable, "-c", _TPU_SNIPPET],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    assert "TPU-PATH-OK" in out.stdout
