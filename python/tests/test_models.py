"""L2 model checks: shapes, determinism, and loss-decreases-under-SGD."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import VARIANTS, make_avg_step, make_train_step

_DTYPES = {"f32": np.float32, "i32": np.int32}


def _fake_batch(io_x, io_y, seed, classes=None, vocab=None):
    r = np.random.default_rng(seed)
    (xs, xd), (ys, yd) = io_x, io_y
    if xd == "f32":
        x = r.standard_normal(xs).astype(np.float32)
    else:
        hi = vocab if vocab else 2
        x = r.integers(0, hi, size=xs).astype(np.int32)
    if yd == "i32":
        hi = classes if classes else 2
        y = r.integers(0, hi, size=ys).astype(np.int32)
    else:
        y = r.uniform(0.5, 5.0, size=ys).astype(np.float32)
    return x, y


def _batch_for(v, seed, eval_io=False):
    io_x = v.eval_x if eval_io else v.train_x
    io_y = v.eval_y if eval_io else v.train_y
    meta = v.meta or {}
    classes = meta.get("classes")
    vocab = meta.get("vocab")
    if v.kind == "matfact":
        r = np.random.default_rng(seed)
        b = io_x[0][0]
        x = np.stack(
            [
                r.integers(0, meta["users"], size=b),
                r.integers(0, meta["items"], size=b),
            ],
            axis=1,
        ).astype(np.int32)
        y = r.uniform(0.5, 5.0, size=(b,)).astype(np.float32)
        return x, y
    return _fake_batch(io_x, io_y, seed, classes=classes, vocab=vocab)


@pytest.mark.parametrize("name", sorted(VARIANTS))
def test_init_shape_and_determinism(name):
    v = VARIANTS[name]
    p1 = v.init(42)
    p2 = v.init(42)
    p3 = v.init(43)
    assert p1.shape == (v.param_count,)
    assert p1.dtype == np.float32
    assert np.array_equal(p1, p2)
    assert not np.array_equal(p1, p3)


@pytest.mark.parametrize("name", sorted(VARIANTS))
def test_model_bytes_close_to_paper(name):
    """Parameter byte counts must track the paper's Table 3 (±10%)."""
    paper_bytes = {
        "cifar10": 346 * 1024,
        "celeba": 124 * 1024,
        "femnist": 6.7 * 1024 * 1024,
        "movielens": 827 * 1024,
        "transformer": None,  # ours, no paper target
    }
    target = paper_bytes[name]
    if target is None:
        return
    ours = VARIANTS[name].param_count * 4
    assert abs(ours - target) / target < 0.10, (ours, target)


@pytest.mark.parametrize("name", sorted(VARIANTS))
def test_train_step_decreases_loss(name):
    v = VARIANTS[name]
    step = make_train_step(v.loss)
    params = jnp.asarray(v.init(0))
    vel = jnp.zeros_like(params)
    x, y = _batch_for(v, 0)
    lr = jnp.float32(v.lr)
    mu = jnp.float32(v.momentum)
    first = None
    for i in range(8):
        params, vel, loss = step(params, vel, x, y, lr, mu)
        if first is None:
            first = float(loss)
    assert float(loss) < first, f"{name}: {first} -> {float(loss)}"
    assert params.shape == (v.param_count,)
    assert np.all(np.isfinite(np.asarray(params)))


@pytest.mark.parametrize("name", sorted(VARIANTS))
def test_eval_step_bounds(name):
    v = VARIANTS[name]
    params = jnp.asarray(v.init(0))
    x, y = _batch_for(v, 1, eval_io=True)
    metric, loss = v.evaluate(params, x, y)
    n = x.shape[0] * (x.shape[1] if v.kind == "lm" else 1)
    assert np.isfinite(float(loss))
    if v.kind in ("classifier", "lm"):
        assert 0 <= float(metric) <= n
    else:
        assert float(metric) >= 0


def test_avg_step_mixes_models():
    v = VARIANTS["celeba"]
    avg_step = make_avg_step()
    p0 = jnp.asarray(v.init(0))
    p1 = jnp.asarray(v.init(1))
    stack = jnp.zeros((v.smax, v.param_count), jnp.float32)
    stack = stack.at[0].set(p0).at[1].set(p1)
    mask = jnp.zeros((v.smax,), jnp.float32).at[0].set(1.0).at[1].set(1.0)
    (out,) = avg_step(stack, mask, jnp.float32(2.0))
    np.testing.assert_allclose(
        np.asarray(out), (np.asarray(p0) + np.asarray(p1)) / 2, rtol=1e-5, atol=1e-6
    )


def test_momentum_accelerates_cifar():
    """Sanity: with mu=0.9 the velocity actually accumulates."""
    v = VARIANTS["cifar10"]
    step = make_train_step(v.loss)
    params = jnp.asarray(v.init(0))
    vel = jnp.zeros_like(params)
    x, y = _batch_for(v, 2)
    _, vel1, _ = step(params, vel, x, y, jnp.float32(v.lr), jnp.float32(0.9))
    p2, vel2, _ = step(params, vel1, x, y, jnp.float32(v.lr), jnp.float32(0.9))
    assert float(jnp.linalg.norm(vel2)) > float(jnp.linalg.norm(vel1)) * 1.05
