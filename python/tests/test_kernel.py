"""Kernel-vs-reference correctness: the core L1 signal.

Every Pallas kernel is checked against its pure-jnp oracle in
``compile.kernels.ref`` with hypothesis sweeping shapes and seeds, exactly
as DESIGN.md §7 prescribes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import avg, dense, ref, sgd

_SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# dense forward
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_fwd_matches_ref(m, k, n, seed):
    r = _rng(seed)
    x = r.standard_normal((m, k)).astype(np.float32)
    w = r.standard_normal((k, n)).astype(np.float32)
    b = r.standard_normal((n,)).astype(np.float32)
    got = dense.dense(x, w, b)
    want = ref.dense(x, w, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_dense_fwd_tiled_path():
    """Shapes larger than the 128 block cap exercise the multi-tile grid."""
    r = _rng(0)
    x = r.standard_normal((256, 160)).astype(np.float32)
    w = r.standard_normal((160, 384)).astype(np.float32)
    b = r.standard_normal((384,)).astype(np.float32)
    got = dense.dense(x, w, b)
    assert_allclose(np.asarray(got), np.asarray(ref.dense(x, w, b)), rtol=1e-4, atol=1e-4)


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_vjp_matches_jnp_grads(m, k, n, seed):
    r = _rng(seed)
    x = r.standard_normal((m, k)).astype(np.float32)
    w = r.standard_normal((k, n)).astype(np.float32)
    b = r.standard_normal((n,)).astype(np.float32)

    def via_kernel(x, w, b):
        return jnp.sum(dense.dense(x, w, b) ** 2)

    def via_jnp(x, w, b):
        return jnp.sum((x @ w + b) ** 2)

    gk = jax.grad(via_kernel, argnums=(0, 1, 2))(x, w, b)
    gj = jax.grad(via_jnp, argnums=(0, 1, 2))(x, w, b)
    for a, bb in zip(gk, gj):
        assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-4, atol=1e-4)


def test_dense_bwd_kernels_direct():
    r = _rng(3)
    x = r.standard_normal((20, 128)).astype(np.float32)
    w = r.standard_normal((128, 232)).astype(np.float32)
    g = r.standard_normal((20, 232)).astype(np.float32)
    assert_allclose(
        np.asarray(dense._dense_dx_pallas(g, w)),
        np.asarray(ref.dense_dx(g, w)),
        rtol=1e-4,
        atol=1e-4,
    )
    assert_allclose(
        np.asarray(dense._dense_dw_pallas(x, g)),
        np.asarray(ref.dense_dw(x, g)),
        rtol=1e-4,
        atol=1e-4,
    )


def test_tile_helper():
    # TPU cap (128): MXU-edge tiles.
    assert dense._tile(128, 128) == 128
    assert dense._tile(256, 128) == 128
    assert dense._tile(20, 128) == 20
    assert dense._tile(1232, 128) == 112
    assert dense._tile(7, 128) == 7
    assert dense._tile(254, 128) == 127
    # worst case: prime > cap degrades to 1 but never fails
    assert dense._tile(131, 128) == 1
    # default (CPU) cap keeps most model dims single-tile
    assert dense._tile(1232) == 1232
    assert dense._tile(4096) == 2048


# ---------------------------------------------------------------------------
# sgd update
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    p=st.integers(1, 20_000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_matches_ref(p, lr, mu, seed):
    r = _rng(seed)
    params = r.standard_normal(p).astype(np.float32)
    vel = r.standard_normal(p).astype(np.float32)
    grads = r.standard_normal(p).astype(np.float32)
    lr_a = jnp.float32(lr)
    mu_a = jnp.float32(mu)
    got_p, got_v = sgd.sgd_update(params, vel, grads, lr_a, mu_a)
    want_p, want_v = ref.sgd_update(params, vel, grads, lr_a, mu_a)
    assert_allclose(np.asarray(got_p), np.asarray(want_p), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-5, atol=1e-6)


def test_sgd_mu_zero_is_plain_sgd():
    """mu=0 must equal p - lr*g regardless of the incoming velocity."""
    r = _rng(7)
    params = r.standard_normal(1000).astype(np.float32)
    vel = r.standard_normal(1000).astype(np.float32)  # arbitrary garbage
    grads = r.standard_normal(1000).astype(np.float32)
    got_p, got_v = sgd.sgd_update(
        params, vel, grads, jnp.float32(0.1), jnp.float32(0.0)
    )
    assert_allclose(np.asarray(got_p), params - 0.1 * grads, rtol=1e-5, atol=1e-7)
    assert_allclose(np.asarray(got_v), grads, rtol=1e-5, atol=1e-7)


def test_sgd_exact_tile_multiple():
    p = 8 * 1024 * 2  # exactly two tiles, no padding branch
    r = _rng(9)
    params = r.standard_normal(p).astype(np.float32)
    vel = np.zeros(p, np.float32)
    grads = r.standard_normal(p).astype(np.float32)
    got_p, _ = sgd.sgd_update(params, vel, grads, jnp.float32(0.5), jnp.float32(0.0))
    assert_allclose(np.asarray(got_p), params - 0.5 * grads, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# masked mean (aggregation)
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    smax=st.integers(1, 16),
    p=st.integers(1, 20_000),
    live=st.data(),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_mean_matches_ref(smax, p, live, seed):
    r = _rng(seed)
    count = live.draw(st.integers(1, smax))
    stack = r.standard_normal((smax, p)).astype(np.float32)
    mask = np.zeros(smax, np.float32)
    mask[:count] = 1.0
    stack[count:] = 0.0  # rust zero-pads dead rows
    got = avg.masked_mean(stack, mask, jnp.float32(count))
    want = ref.masked_mean(stack, mask, jnp.float32(count))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_masked_mean_ignores_masked_rows():
    """Garbage in masked-out rows must not leak into the mean."""
    r = _rng(11)
    stack = r.standard_normal((4, 100)).astype(np.float32)
    stack[2:] = 1e9  # poison the dead rows
    mask = np.array([1, 1, 0, 0], np.float32)
    got = avg.masked_mean(stack, mask, jnp.float32(2.0))
    want = (stack[0] + stack[1]) / 2.0
    assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_masked_mean_single_model_identity():
    r = _rng(13)
    stack = np.zeros((8, 500), np.float32)
    stack[0] = r.standard_normal(500).astype(np.float32)
    mask = np.zeros(8, np.float32)
    mask[0] = 1.0
    got = avg.masked_mean(stack, mask, jnp.float32(1.0))
    assert_allclose(np.asarray(got), stack[0], rtol=1e-6)
